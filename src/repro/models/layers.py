"""Transformer / SSM building blocks shared by every assigned architecture.

Conventions:
* params are nested dicts of arrays; specs built by ``*_spec`` functions
  (single source of truth, see models/spec.py);
* every ``*_apply`` takes a ``cst(x, axes)`` callback that applies a
  logical sharding constraint (identity on a single device);
* activations use cfg.dtype (bf16); norms/softmax accumulate in f32.

Logical activation axes used throughout:
  'batch'   -> data axes,  'seq' -> sequence (SP where enabled),
  'heads'/'mlp'/'experts' -> model axis, 'embed'/'head_dim'/'state' -> none.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .config import ArchConfig
from .spec import ParamSpec

Params = Dict[str, Any]


def _id_cst(x, axes):
    return x


def dus_seq(buf: jnp.ndarray, upd: jnp.ndarray, pos, axis: int = 1):
    """dynamic_update_slice at position ``pos`` along ``axis`` (index dtypes
    unified — x64 mode would otherwise mix int32/int64 literals)."""
    z = jnp.zeros((), dtype=jnp.asarray(pos).dtype)
    idx = tuple(jnp.asarray(pos) if i == axis else z
                for i in range(buf.ndim))
    return lax.dynamic_update_slice(buf, upd.astype(buf.dtype), idx)


# ----------------------------------------------------------------------------
# Norms
# ----------------------------------------------------------------------------


def rmsnorm_spec(d: int) -> Params:
    return {"scale": ParamSpec((d,), ("embed",), init="ones")}


def rmsnorm_apply(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_spec(d: int) -> Params:
    return {"scale": ParamSpec((d,), ("embed",), init="ones"),
            "bias": ParamSpec((d,), ("embed",), init="zeros")}


def layernorm_apply(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


# ----------------------------------------------------------------------------
# Rotary embeddings (standard + 3-component M-RoPE for qwen2-vl)
# ----------------------------------------------------------------------------


def rope_freqs(dim: int, theta: float, positions: jnp.ndarray) -> Tuple:
    """positions: (..., S) int -> cos/sin of shape (..., S, dim//2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray):
    """x: (B, S, H, D); cos/sin: (B, S, D/2) or (S, D/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def mrope_cos_sin(dim: int, theta: float, pos3: jnp.ndarray):
    """Simplified M-RoPE: pos3 (B, S, 3) = (t, h, w) position components.

    The rotary dim is split 2:1:1 between temporal/height/width components
    (qwen2-vl's mrope_section), then the per-section cos/sin are
    concatenated — equivalent to rotating disjoint channel groups by
    different position ids.
    """
    half = dim // 2
    sec = (half // 2, half // 4, half - half // 2 - half // 4)
    parts_c, parts_s = [], []
    start = 0
    for comp in range(3):
        inv = 1.0 / (theta ** (jnp.arange(start, start + sec[comp],
                                          dtype=jnp.float32) * 2 / dim))
        ang = pos3[..., comp].astype(jnp.float32)[..., None] * inv
        parts_c.append(jnp.cos(ang))
        parts_s.append(jnp.sin(ang))
        start += sec[comp]
    return jnp.concatenate(parts_c, -1), jnp.concatenate(parts_s, -1)


# ----------------------------------------------------------------------------
# GQA attention (with optional bias, sliding window, KV cache)
# ----------------------------------------------------------------------------


def attention_spec(cfg: ArchConfig, d_in: Optional[int] = None,
                   d_out: Optional[int] = None) -> Params:
    d = d_in or cfg.d_model
    do = d_out or cfg.d_model
    hd = cfg.hd
    p = {
        "wq": ParamSpec((d, cfg.n_heads, hd), ("embed", "heads", "head_dim"),
                        cfg.dtype, init="scaled"),
        "wk": ParamSpec((d, cfg.n_kv_heads, hd), ("embed", "kv_heads", "head_dim"),
                        cfg.dtype, init="scaled"),
        "wv": ParamSpec((d, cfg.n_kv_heads, hd), ("embed", "kv_heads", "head_dim"),
                        cfg.dtype, init="scaled"),
        "wo": ParamSpec((cfg.n_heads, hd, do), ("heads", "head_dim", "embed"),
                        cfg.dtype, init="scaled"),
    }
    if cfg.qkv_bias:
        p["bq"] = ParamSpec((cfg.n_heads, hd), ("heads", "head_dim"),
                            cfg.dtype, init="zeros")
        p["bk"] = ParamSpec((cfg.n_kv_heads, hd), ("kv_heads", "head_dim"),
                            cfg.dtype, init="zeros")
        p["bv"] = ParamSpec((cfg.n_kv_heads, hd), ("kv_heads", "head_dim"),
                            cfg.dtype, init="zeros")
    return p


ATTN_KV_CHUNK = 1024  # blockwise-softmax KV chunk (memory/perf knob)


def _sdpa(q, k, v, *, causal: bool, window: int = 0,
          q_offset: Optional[jnp.ndarray] = None,
          kv_len: Optional[jnp.ndarray] = None,
          kv_chunk: int = 0):
    """Blockwise (flash-style) attention: q (B,Sq,H,Dq), k (B,Sk,KVH,Dq),
    v (B,Sk,KVH,Dv) -> (B,Sq,H,Dv).  f32 running softmax over KV chunks —
    never materializes the (Sq, Sk) score matrix, so 32k prefill and 500k
    caches stay within HBM.

    q_offset: absolute position of q[0] (decode); kv_len: number of valid
    cache entries (the rest are masked).
    """
    B, Sq, H, Dq = q.shape
    KVH = k.shape[2]
    Dv = v.shape[-1]
    rep = H // KVH
    Sk = k.shape[1]
    C = kv_chunk or min(ATTN_KV_CHUNK, Sk)
    # pad KV to a multiple of the chunk (masked off via kv_len logic)
    pad = (-Sk) % C
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = (Sk + pad) // C
    valid_len = kv_len if kv_len is not None else Sk

    qf = (q.astype(jnp.float32) / math.sqrt(Dq)).reshape(B, Sq, KVH, rep, Dq)
    qpos = jnp.arange(Sq)[:, None] + (q_offset if q_offset is not None else 0)

    kc = jnp.moveaxis(k.reshape(B, n_chunks, C, KVH, Dq), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, n_chunks, C, KVH, Dv), 1, 0)

    def chunk_step(carry, inputs):
        m, l, acc = carry
        ci, kb, vb = inputs                      # kb: (B,C,KVH,Dq)
        logits = jnp.einsum("bqgrd,bkgd->bgrqk", qf,
                            kb.astype(jnp.float32))   # (B,KVH,rep,Sq,C)
        kpos = ci * C + jnp.arange(C)[None, :]        # (1, C)
        mask = kpos < valid_len
        if causal:
            mask = mask & (kpos <= qpos)
        if window > 0:
            mask = mask & (kpos > qpos - window)
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        scale_old = jnp.exp(m - m_new)
        l = l * scale_old + jnp.sum(p, axis=-1)
        acc = acc * scale_old[..., None] + jnp.einsum(
            "bgrqk,bkgd->bgrqd", p, vb.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((B, KVH, rep, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, KVH, rep, Sq), jnp.float32)
    a0 = jnp.zeros((B, KVH, rep, Sq, Dv), jnp.float32)
    (m, l, acc), _ = lax.scan(chunk_step, (m0, l0, a0),
                              (jnp.arange(n_chunks), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out, 3, 1).reshape(B, Sq, H, Dv)
    return out.astype(q.dtype)


def attention_apply(p: Params, cfg: ArchConfig, x: jnp.ndarray,
                    cos: jnp.ndarray, sin: jnp.ndarray, *,
                    cst: Callable = _id_cst, causal: bool = True,
                    cache: Optional[Dict] = None,
                    use_rope: bool = True):
    """Returns (out, new_cache).  cache = {'k','v','pos'} for decode."""
    B, S, d = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"][None, None]
        k = k + p["bk"][None, None]
        v = v + p["bv"][None, None]
    q = cst(q, ("batch", "seq", "heads", "head_dim"))
    k = cst(k, ("batch", "seq", "kv_heads", "head_dim"))
    if use_rope:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    new_cache = None
    if cache is not None:
        pos = cache["pos"]                      # scalar int: filled length
        ck = dus_seq(cache["k"], k, pos)
        cv = dus_seq(cache["v"], v, pos)
        out = _sdpa(q, ck, cv, causal=causal, window=cfg.sliding_window,
                    q_offset=pos, kv_len=pos + S)
        new_cache = {"k": ck, "v": cv, "pos": pos + S}
    else:
        out = _sdpa(q, k, v, causal=causal, window=cfg.sliding_window)
    out = cst(out, ("batch", "seq", "heads", "head_dim"))
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return cst(y, ("batch", "seq", "embed")), new_cache


def cross_attention_apply(p: Params, cfg: ArchConfig, x: jnp.ndarray,
                          kv_src: jnp.ndarray, *, cst: Callable = _id_cst):
    """Encoder-decoder cross attention (whisper); no rope, no cache mask."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", kv_src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kv_src, p["wv"])
    out = _sdpa(q, k, v, causal=False)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return cst(y, ("batch", "seq", "embed"))


# ----------------------------------------------------------------------------
# MLA — multi-head latent attention (deepseek-v3), with latent KV cache
# ----------------------------------------------------------------------------


def mla_spec(cfg: ArchConfig) -> Params:
    d = cfg.d_model
    H = cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "wq_a": ParamSpec((d, qr), ("embed", "q_lora"), cfg.dtype, "scaled"),
        "q_norm": rmsnorm_spec(qr),
        "wq_b": ParamSpec((qr, H, dn + dr), ("q_lora", "heads", "head_dim"),
                          cfg.dtype, "scaled"),
        "wkv_a": ParamSpec((d, kvr + dr), ("embed", "kv_lora"), cfg.dtype,
                           "scaled"),
        "kv_norm": rmsnorm_spec(kvr),
        "wkv_b": ParamSpec((kvr, H, dn + dv), ("kv_lora", "heads", "head_dim"),
                           cfg.dtype, "scaled"),
        "wo": ParamSpec((H, dv, d), ("heads", "head_dim", "embed"),
                        cfg.dtype, "scaled"),
    }


def mla_apply(p: Params, cfg: ArchConfig, x: jnp.ndarray,
              positions: jnp.ndarray, *, cst: Callable = _id_cst,
              cache: Optional[Dict] = None):
    """MLA with decoupled RoPE.  cache stores the *latent* c_kv (+ rope key)
    — the low-storage KV cache that is MLA's whole point: (kvr + dr) per
    token instead of 2*H*hd."""
    B, S, d = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    # --- queries ---
    q_lat = jnp.einsum("bsd,dr->bsr", x, p["wq_a"])
    q_lat = rmsnorm_apply(p["q_norm"], q_lat, cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", q_lat, p["wq_b"])      # (B,S,H,dn+dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    # --- compressed kv + decoupled rope key ---
    ckv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])          # (B,S,kvr+dr)
    c_kv, k_rope = ckv[..., :kvr], ckv[..., kvr:]
    cos, sin = rope_freqs(dr, cfg.rope_theta, positions)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)    # (B,S,1,dr)
    new_cache = None
    if cache is not None:
        pos = cache["pos"]
        c_all = dus_seq(cache["c_kv"], c_kv, pos)
        kr_all = dus_seq(cache["k_rope"], k_rope[:, :, 0, :], pos)
        new_cache = {"c_kv": c_all, "k_rope": kr_all, "pos": pos + S}
        c_use, kr_use, kv_len, q_off = c_all, kr_all, pos + S, pos
    else:
        c_use, kr_use, kv_len, q_off = c_kv, k_rope[:, :, 0, :], None, None
    c_use = rmsnorm_apply(p["kv_norm"], c_use, cfg.norm_eps)
    k_nope = jnp.einsum("btr,rhk->bthk", c_use, p["wkv_b"][..., :dn])
    vv = jnp.einsum("btr,rhk->bthk", c_use, p["wkv_b"][..., dn:])
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr_use[:, :, None, :],
                                  (*kr_use.shape[:2], H, dr))], axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    q_full = cst(q_full, ("batch", "seq", "heads", "head_dim"))
    out = _sdpa(q_full, k_full, vv, causal=True,
                q_offset=q_off, kv_len=kv_len)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return cst(y, ("batch", "seq", "embed")), new_cache


# ----------------------------------------------------------------------------
# MLPs
# ----------------------------------------------------------------------------


def swiglu_spec(cfg: ArchConfig, d_ff: Optional[int] = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {
        "w1": ParamSpec((d, f), ("embed", "mlp"), cfg.dtype, "scaled"),
        "w3": ParamSpec((d, f), ("embed", "mlp"), cfg.dtype, "scaled"),
        "w2": ParamSpec((f, d), ("mlp", "embed"), cfg.dtype, "scaled"),
    }


def swiglu_apply(p: Params, x: jnp.ndarray, *, cst: Callable = _id_cst):
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["w1"])) * \
        jnp.einsum("bsd,df->bsf", x, p["w3"])
    h = cst(h, ("batch", "seq", "mlp"))
    return cst(jnp.einsum("bsf,fd->bsd", h, p["w2"]),
               ("batch", "seq", "embed"))


def gelu_mlp_spec(cfg: ArchConfig, d_ff: Optional[int] = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {
        "w1": ParamSpec((d, f), ("embed", "mlp"), cfg.dtype, "scaled"),
        "b1": ParamSpec((f,), ("mlp",), cfg.dtype, "zeros"),
        "w2": ParamSpec((f, d), ("mlp", "embed"), cfg.dtype, "scaled"),
        "b2": ParamSpec((d,), ("embed",), cfg.dtype, "zeros"),
    }


def gelu_mlp_apply(p: Params, x: jnp.ndarray, *, cst: Callable = _id_cst):
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w1"]) + p["b1"])
    h = cst(h, ("batch", "seq", "mlp"))
    return cst(jnp.einsum("bsf,fd->bsd", h, p["w2"]) + p["b2"],
               ("batch", "seq", "embed"))


# ----------------------------------------------------------------------------
# MoE — specs + the reference dense path.  The scalable EP path (shard_map
# + all_to_all) lives in moe_ep.py; both consume these specs.
# ----------------------------------------------------------------------------


def moe_spec(cfg: ArchConfig) -> Params:
    d, f = cfg.d_model, cfg.moe_d_ff or cfg.d_ff
    E = cfg.n_experts
    p = {
        "router": ParamSpec((d, E), ("embed", None), jnp.float32, "scaled"),
        "w1": ParamSpec((E, d, f), ("experts", "embed", "expert_mlp"),
                        cfg.dtype, "scaled"),
        "w3": ParamSpec((E, d, f), ("experts", "embed", "expert_mlp"),
                        cfg.dtype, "scaled"),
        "w2": ParamSpec((E, f, d), ("experts", "expert_mlp", "embed"),
                        cfg.dtype, "scaled"),
    }
    if cfg.n_shared_experts > 0:
        fs = f * cfg.n_shared_experts
        p["shared"] = {
            "w1": ParamSpec((d, fs), ("embed", "mlp"), cfg.dtype, "scaled"),
            "w3": ParamSpec((d, fs), ("embed", "mlp"), cfg.dtype, "scaled"),
            "w2": ParamSpec((fs, d), ("mlp", "embed"), cfg.dtype, "scaled"),
        }
    return p


def router_topk(logits: jnp.ndarray, k: int, impl: str):
    """logits (T, E) -> (weights (T,k), ids (T,k)); weights sum to 1."""
    if impl == "sigmoid":                    # deepseek-v3 style scoring
        scores = jax.nn.sigmoid(logits.astype(jnp.float32))
        w, ids = lax.top_k(scores, k)
        w = w / (jnp.sum(w, -1, keepdims=True) + 1e-20)
    else:
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        w, ids = lax.top_k(probs, k)
        w = w / (jnp.sum(w, -1, keepdims=True) + 1e-20)
    return w, ids


def moe_dense_apply(p: Params, cfg: ArchConfig, x: jnp.ndarray, *,
                    cst: Callable = _id_cst):
    """Reference dense MoE: every expert computed on every token, combined
    with routing weights.  Exact (no capacity drops) — the oracle for the
    EP path, and the smoke-test path for tiny configs."""
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    w, ids = router_topk(logits, cfg.experts_per_tok, cfg.router_impl)
    E = cfg.n_experts
    # dense: (T, E) combine weights
    comb = jnp.zeros((T, E), jnp.float32)
    comb = comb.at[jnp.arange(T)[:, None], ids].add(w)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xt, p["w1"])) * \
        jnp.einsum("td,edf->tef", xt, p["w3"])
    y = jnp.einsum("tef,efd->ted", h, p["w2"])
    out = jnp.einsum("ted,te->td", y.astype(jnp.float32), comb)
    out = out.astype(x.dtype).reshape(B, S, d)
    if "shared" in p:
        out = out + swiglu_apply(p["shared"], x, cst=cst)
    return cst(out, ("batch", "seq", "embed"))
