"""Recurrent sequence-mixing blocks: Mamba2 (zamba2) and xLSTM (sLSTM/mLSTM).

These give the two sub-quadratic architectures their O(1)-state decode
path (long_500k).  Both are written as a *scan* (train/prefill) plus a
*single-step* form (decode) sharing the same cell function — the same
structure the paper's integrators use (one step function, outer loop
owned by the driver).

State-of-the-art chunked/blocked forms (SSD) are a perf optimization on
real hardware; the recurrence here is the semantic reference and lowers
compactly (one scan body) for the dry-run.  Sharding: heads over 'model'.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

from .config import ArchConfig
from .spec import ParamSpec
from . import layers

Params = Dict[str, Any]


# ----------------------------------------------------------------------------
# Mamba2
# ----------------------------------------------------------------------------


def mamba2_dims(cfg: ArchConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    nheads = d_in // cfg.ssm_head_dim
    return d_in, nheads, cfg.ssm_state, cfg.ssm_head_dim


def mamba2_spec(cfg: ArchConfig) -> Params:
    d = cfg.d_model
    d_in, nh, ds, hd = mamba2_dims(cfg)
    conv_dim = d_in + 2 * ds
    return {
        "in_proj": ParamSpec((d, 2 * d_in + 2 * ds + nh),
                             ("embed", "mlp"), cfg.dtype, "scaled"),
        "conv_w": ParamSpec((cfg.ssm_conv, conv_dim), (None, "mlp"),
                            cfg.dtype, "scaled"),
        "conv_b": ParamSpec((conv_dim,), ("mlp",), cfg.dtype, "zeros"),
        "A_log": ParamSpec((nh,), ("heads",), jnp.float32, "zeros"),
        "D": ParamSpec((nh,), ("heads",), jnp.float32, "ones"),
        "dt_bias": ParamSpec((nh,), ("heads",), jnp.float32, "zeros"),
        "norm": layers.rmsnorm_spec(d_in),
        "out_proj": ParamSpec((d_in, d), ("mlp", "embed"), cfg.dtype,
                              "scaled"),
    }


def _mamba2_inner(p, cfg, xz, conv_state):
    """Split in_proj output and run the causal conv.

    xz: (B, S, 2*d_in + 2*ds + nh).  conv_state: (B, K-1, conv_dim) or None.
    Returns (z, xBC_conved, dt, new_conv_state).
    """
    d_in, nh, ds, hd = mamba2_dims(cfg)
    z = xz[..., :d_in]
    xBC = xz[..., d_in:d_in + d_in + 2 * ds]
    dt = xz[..., -nh:]
    K = cfg.ssm_conv
    if conv_state is None:
        pad = jnp.zeros_like(xBC[:, : K - 1])
        seq = jnp.concatenate([pad, xBC], axis=1)
        new_state = seq[:, -(K - 1):]
    else:
        seq = jnp.concatenate([conv_state, xBC], axis=1)
        new_state = seq[:, -(K - 1):]
    # causal depthwise conv, kernel K
    out = jnp.zeros_like(xBC)
    for k in range(K):
        out = out + seq[:, k:k + xBC.shape[1]] * p["conv_w"][k][None, None]
    xBC = jax.nn.silu(out + p["conv_b"][None, None])
    return z, xBC, dt, new_state


MAMBA2_CHUNK = 128  # SSD chunk length (perf knob; see EXPERIMENTS §Perf)


def _ssm_scan_stepwise(xs, Bmat, Cmat, decay, dt, h0):
    """Reference per-timestep recurrence.  xs:(B,S,nh,hd) f32,
    Bmat/Cmat:(B,S,ds), decay/dt:(B,S,nh), h0:(B,nh,hd,ds)."""

    def cell(h, inputs):
        xt, Bt, Ct, dct, dtt = inputs
        upd = jnp.einsum("bnh,bs->bnhs", xt * dtt[..., None],
                         Bt.astype(jnp.float32))
        h = h * dct[..., None, None] + upd
        yt = jnp.einsum("bnhs,bs->bnh", h, Ct.astype(jnp.float32))
        return h, yt

    seq_inputs = (jnp.moveaxis(xs, 1, 0), jnp.moveaxis(Bmat, 1, 0),
                  jnp.moveaxis(Cmat, 1, 0), jnp.moveaxis(decay, 1, 0),
                  jnp.moveaxis(dt, 1, 0))
    hT, ys = lax.scan(cell, h0, seq_inputs)
    return jnp.moveaxis(ys, 0, 1), hT


def _ssm_scan_chunked(xs, Bmat, Cmat, logdecay, dt, h0, chunk: int):
    """Chunked SSD (Mamba-2's blocked algorithm) — mathematically equal to
    the per-step recurrence but with O(S/chunk) state round-trips and
    MXU-friendly (C x C) matmuls.  This is the paper-style hardware
    adaptation of §Perf: state stays in VMEM for a whole chunk.

    xs: (B,S,nh,hd) f32; Bmat/Cmat: (B,S,ds); logdecay/dt: (B,S,nh);
    h0: (B,nh,hd,ds).  Requires S % chunk == 0.
    """
    B, S, nh, hd = xs.shape
    ds = Bmat.shape[-1]
    nc = S // chunk
    u = xs * dt[..., None]                       # effective input
    # reshape to chunks
    uc = u.reshape(B, nc, chunk, nh, hd)
    Bc = Bmat.reshape(B, nc, chunk, ds).astype(jnp.float32)
    Cc = Cmat.reshape(B, nc, chunk, ds).astype(jnp.float32)
    ld = logdecay.reshape(B, nc, chunk, nh)
    s = jnp.cumsum(ld, axis=2)                   # inclusive log-decay
    # intra-chunk: M[i,j] = (C_i . B_j) * exp(s_i - s_j) for j <= i
    G = jnp.einsum("bncs,bnks->bnck", Cc, Bc)    # (B,nc,C,C)
    delta = s[:, :, :, None, :] - s[:, :, None, :, :]   # (B,nc,C,C,nh)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    Dm = jnp.where(causal[None, None, :, :, None], jnp.exp(delta), 0.0)
    M = G[..., None] * Dm                        # (B,nc,C,C,nh)
    y_intra = jnp.einsum("bnckh,bnkhd->bnchd", M, uc)
    # inter-chunk: scan over chunks carrying h (B,nh,hd,ds)
    w_in = jnp.exp(s)                            # state->output decay
    w_out = jnp.exp(s[:, :, -1:, :] - s)         # input->chunk-end decay
    a_chunk = jnp.exp(s[:, :, -1, :])            # total chunk decay
    # state ingredients per chunk: hupd = sum_j w_out_j * u_j (x) B_j
    hupd = jnp.einsum("bnchd,bnch,bncs->bnhds",
                      uc, w_out, Bc)             # (B,nc,nh,hd,ds)

    def chunk_cell(h, inputs):
        yi, win, hup, ac, Ci = inputs
        # y_inter[i] = win_i * (C_i . h)
        y_inter = jnp.einsum("bcs,bhds,bch->bchd", Ci, h, win)
        h = h * ac[:, :, None, None] + hup
        return h, yi + y_inter

    per_chunk = (jnp.moveaxis(y_intra, 1, 0),
                 jnp.moveaxis(w_in, 1, 0),
                 jnp.moveaxis(hupd, 1, 0),
                 jnp.moveaxis(a_chunk, 1, 0),
                 jnp.moveaxis(Cc, 1, 0))
    hT, ys = lax.scan(chunk_cell, h0, per_chunk)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, nh, hd)
    return y, hT


def mamba2_apply(p: Params, cfg: ArchConfig, x: jnp.ndarray, *,
                 cst: Callable = layers._id_cst,
                 cache: Optional[Dict] = None,
                 chunk: Optional[int] = None):
    """x: (B, S, d).  cache = {'conv': (B,K-1,conv_dim),
    'ssm': (B,nh,hd,ds)} for decode; None for train (zero init).

    Train/prefill uses the chunked SSD path when S % chunk == 0 (else the
    stepwise reference); decode is a single recurrence step.
    """
    import os
    if chunk is None:  # env override enables §Perf A/B comparisons
        chunk = int(os.environ.get("REPRO_SSM_CHUNK", MAMBA2_CHUNK))
    B, S, d = x.shape
    d_in, nh, ds, hd = mamba2_dims(cfg)
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    conv_state = cache["conv"] if cache is not None else None
    z, xBC, dt, new_conv = _mamba2_inner(p, cfg, xz, conv_state)
    xs = xBC[..., :d_in].reshape(B, S, nh, hd)
    Bmat = xBC[..., d_in:d_in + ds]                      # (B,S,ds)
    Cmat = xBC[..., d_in + ds:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         p["dt_bias"][None, None])       # (B,S,nh)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))         # (nh,)
    logdecay = dt * A[None, None]                        # (B,S,nh), <= 0
    xs = cst(xs, ("batch", "seq", "heads", "head_dim"))
    xs32 = xs.astype(jnp.float32)

    h0 = (cache["ssm"] if cache is not None else
          jnp.zeros((B, nh, hd, ds), jnp.float32))

    if cache is None and chunk > 0 and S % chunk == 0 and S > chunk:
        y, hT = _ssm_scan_chunked(xs32, Bmat, Cmat, logdecay, dt, h0, chunk)
    else:
        y, hT = _ssm_scan_stepwise(xs32, Bmat, Cmat, jnp.exp(logdecay),
                                   dt, h0)
    y = y + xs32 * p["D"][None, None, :, None]
    y = y.reshape(B, S, d_in)
    y = layers.rmsnorm_apply(p["norm"], (y * jax.nn.silu(
        z.astype(jnp.float32))).astype(x.dtype), cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv, "ssm": hT}
    return cst(out, ("batch", "seq", "embed")), new_cache


def mamba2_cache_spec(cfg: ArchConfig, batch: int):
    d_in, nh, ds, hd = mamba2_dims(cfg)
    conv_dim = d_in + 2 * ds
    return {
        "conv": jax.ShapeDtypeStruct((batch, cfg.ssm_conv - 1, conv_dim),
                                     cfg.dtype),
        "ssm": jax.ShapeDtypeStruct((batch, nh, hd, ds), jnp.float32),
    }


# ----------------------------------------------------------------------------
# xLSTM: mLSTM (matrix memory) and sLSTM (scalar memory w/ recurrence)
# ----------------------------------------------------------------------------


def mlstm_spec(cfg: ArchConfig) -> Params:
    d = cfg.d_model
    H = cfg.n_heads
    d_up = 2 * d      # pf=2 up-projection (xLSTM paper)
    return {
        "up": ParamSpec((d, 2 * d_up), ("embed", "mlp"), cfg.dtype, "scaled"),
        "wq": ParamSpec((d_up, d_up), ("mlp", "heads_x"), cfg.dtype, "scaled"),
        "wk": ParamSpec((d_up, d_up), ("mlp", "heads_x"), cfg.dtype, "scaled"),
        "wv": ParamSpec((d_up, d_up), ("mlp", "heads_x"), cfg.dtype, "scaled"),
        "wi": ParamSpec((d_up, H), ("mlp", "heads"), jnp.float32, "scaled"),
        "wf": ParamSpec((d_up, H), ("mlp", "heads"), jnp.float32, "scaled"),
        "bi": ParamSpec((H,), ("heads",), jnp.float32, "zeros"),
        "bf": ParamSpec((H,), ("heads",), jnp.float32, "ones"),
        "norm": layers.rmsnorm_spec(d_up),
        "down": ParamSpec((d_up, d), ("mlp", "embed"), cfg.dtype, "scaled"),
    }


def mlstm_apply(p: Params, cfg: ArchConfig, x: jnp.ndarray, *,
                cst: Callable = layers._id_cst,
                cache: Optional[Dict] = None):
    """Matrix-memory LSTM with exponential gating + stabilizer state."""
    B, S, d = x.shape
    H = cfg.n_heads
    up = jnp.einsum("bsd,de->bse", x, p["up"])
    d_up = up.shape[-1] // 2
    u, gate_skip = up[..., :d_up], up[..., d_up:]
    dh = d_up // H
    q = jnp.einsum("bse,ef->bsf", u, p["wq"]).reshape(B, S, H, dh)
    k = jnp.einsum("bse,ef->bsf", u, p["wk"]).reshape(B, S, H, dh) / \
        math.sqrt(dh)
    v = jnp.einsum("bse,ef->bsf", u, p["wv"]).reshape(B, S, H, dh)
    ig = (jnp.einsum("bse,eh->bsh", u.astype(jnp.float32), p["wi"])
          + p["bi"])                                     # log input gate
    fg = (jnp.einsum("bse,eh->bsh", u.astype(jnp.float32), p["wf"])
          + p["bf"])
    logf = -jax.nn.softplus(-fg)                          # log sigmoid(f)

    if cache is not None:
        C0, n0, m0 = cache["C"], cache["n"], cache["m"]
    else:
        C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
        n0 = jnp.zeros((B, H, dh), jnp.float32)
        # large-negative finite (NOT -inf: grads through exp(m - m_new)
        # would be NaN); e^-30 ~ 1e-13 makes the first forget term exact 0
        m0 = jnp.full((B, H), -30.0, jnp.float32)

    def cell(carry, inputs):
        C, n, m = carry
        qt, kt, vt, it, lft = inputs                      # (B,H,dh)... (B,H)
        m_new = jnp.maximum(lft + m, it)
        fscale = jnp.exp(lft + m - m_new)                 # (B,H)
        iscale = jnp.exp(it - m_new)
        C = C * fscale[..., None, None] + iscale[..., None, None] * \
            jnp.einsum("bhv,bhk->bhvk", vt.astype(jnp.float32),
                       kt.astype(jnp.float32))
        n = n * fscale[..., None] + iscale[..., None] * kt.astype(jnp.float32)
        num = jnp.einsum("bhvk,bhk->bhv", C, qt.astype(jnp.float32))
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n,
                                             qt.astype(jnp.float32))),
                          jnp.exp(-m_new))
        h = num / den[..., None]
        return (C, n, m_new), h

    seq = (jnp.moveaxis(q, 1, 0), jnp.moveaxis(k, 1, 0),
           jnp.moveaxis(v, 1, 0), jnp.moveaxis(ig, 1, 0),
           jnp.moveaxis(logf, 1, 0))
    (CT, nT, mT), hs = lax.scan(cell, (C0, n0, m0), seq)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, d_up).astype(x.dtype)
    h = layers.rmsnorm_apply(p["norm"], h, cfg.norm_eps)
    h = h * jax.nn.silu(gate_skip)
    out = jnp.einsum("bse,ed->bsd", h, p["down"])
    new_cache = {"C": CT, "n": nT, "m": mT} if cache is not None else None
    return cst(out, ("batch", "seq", "embed")), new_cache


def mlstm_cache_spec(cfg: ArchConfig, batch: int):
    H = cfg.n_heads
    dh = (2 * cfg.d_model) // H
    return {"C": jax.ShapeDtypeStruct((batch, H, dh, dh), jnp.float32),
            "n": jax.ShapeDtypeStruct((batch, H, dh), jnp.float32),
            "m": jax.ShapeDtypeStruct((batch, H), jnp.float32)}


def slstm_spec(cfg: ArchConfig) -> Params:
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    return {
        "W": ParamSpec((d, 4 * d), ("embed", "mlp"), cfg.dtype, "scaled"),
        "R": ParamSpec((H, dh, 4 * dh), ("heads", "head_dim", None),
                       cfg.dtype, "scaled"),
        "b": ParamSpec((4 * d,), ("mlp",), jnp.float32, "zeros"),
        "norm": layers.rmsnorm_spec(d),
        "out": ParamSpec((d, d), ("embed", "embed_out"), cfg.dtype, "scaled"),
    }


def slstm_apply(p: Params, cfg: ArchConfig, x: jnp.ndarray, *,
                cst: Callable = layers._id_cst,
                cache: Optional[Dict] = None):
    """Scalar-memory LSTM with exponential gating, normalizer state and
    block-diagonal (per-head) recurrence — the truly sequential xLSTM cell."""
    B, S, d = x.shape
    H = cfg.n_heads
    dh = d // H
    wx = jnp.einsum("bsd,de->bse", x, p["W"]).astype(jnp.float32) + p["b"]

    if cache is not None:
        c0, n0, h0, m0 = cache["c"], cache["n"], cache["h"], cache["m"]
    else:
        c0 = jnp.zeros((B, d), jnp.float32)
        n0 = jnp.ones((B, d), jnp.float32)
        h0 = jnp.zeros((B, d), jnp.float32)
        m0 = jnp.zeros((B, d), jnp.float32)

    R = p["R"].astype(jnp.float32)

    def cell(carry, wxt):
        c, n, h, m = carry
        hh = h.reshape(B, H, dh)
        rec = jnp.einsum("bhk,hke->bhe", hh, R).reshape(B, 4 * d)
        # gate layout: [i, f, z, o] each (d,)
        g = wxt + rec
        gi, gf, gz, go = jnp.split(g, 4, axis=-1)
        m_new = jnp.maximum(gf + m, gi)                   # stabilizer
        i = jnp.exp(gi - m_new)
        f = jnp.exp(gf + m - m_new)
        z = jnp.tanh(gz)
        o = jax.nn.sigmoid(go)
        c = f * c + i * z
        n = f * n + i
        h = o * c / jnp.maximum(n, 1e-6)
        return (c, n, h, m_new), h

    (cT, nT, hT, mT), hs = lax.scan(cell, (c0, n0, h0, m0),
                                    jnp.moveaxis(wx, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).astype(x.dtype)            # (B,S,d)
    h = layers.rmsnorm_apply(p["norm"], h, cfg.norm_eps)
    out = jnp.einsum("bsd,de->bse", h, p["out"])
    new_cache = ({"c": cT, "n": nT, "h": hT, "m": mT}
                 if cache is not None else None)
    return cst(out, ("batch", "seq", "embed")), new_cache


def slstm_cache_spec(cfg: ArchConfig, batch: int):
    d = cfg.d_model
    return {"c": jax.ShapeDtypeStruct((batch, d), jnp.float32),
            "n": jax.ShapeDtypeStruct((batch, d), jnp.float32),
            "h": jax.ShapeDtypeStruct((batch, d), jnp.float32),
            "m": jax.ShapeDtypeStruct((batch, d), jnp.float32)}
