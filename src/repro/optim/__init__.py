from . import adamw, gradflow

__all__ = ["adamw", "gradflow"]
