"""From-scratch AdamW with global-norm clipping and sharded states.

States are ``tree_map(zeros_like)`` of the params, so under jit they
inherit the parameter shardings (FSDP'd optimizer state = ZeRO).
``moment_dtype`` lets very large models halve optimizer memory
(bf16 moments), the trade-off documented in EXPERIMENTS.md §Dry-run.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import vector as nv


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: Any = jnp.float32
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay (the production default)."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)


def init(params, cfg: AdamWConfig = AdamWConfig()) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree_util.tree_map(zeros, params),
                      v=jax.tree_util.tree_map(zeros, params))


def global_norm(tree):
    """sqrt(sum ||g||^2) — a MeshVector reduction (one collective)."""
    return jnp.sqrt(nv.dot(tree, tree))


def update(grads, state: AdamWState, params,
           cfg: AdamWConfig = AdamWConfig()):
    """Returns (new_params, new_state, stats)."""
    gnorm = global_norm(jax.tree_util.tree_map(
        lambda g: g.astype(jnp.float32), grads))
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + (1 - cfg.b1) * gf
        v32 = v.astype(jnp.float32) * cfg.b2 + (1 - cfg.b2) * gf * gf
        mhat = m32 / b1c
        vhat = v32 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, m32.astype(cfg.moment_dtype), v32.astype(cfg.moment_dtype)

    out = jax.tree_util.tree_map(upd, params, grads, state.m, state.v)
    # unzip the 3-tuples
    newp = jax.tree_util.tree_map(lambda t: t[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
    newm = jax.tree_util.tree_map(lambda t: t[1], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
    newv = jax.tree_util.tree_map(lambda t: t[2], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
    return newp, AdamWState(step=step, m=newm, v=newv), \
        {"grad_norm": gnorm, "lr": lr}
