"""Gradient-flow optimizer: training as an ODE, driven by repro.core.

The bridge feature (DESIGN.md §3): treat  dθ/dt = -∇L(θ)  as the
"full model" SUNDIALS use case and advance it with the paper's adaptive
embedded-pair ERK integrator.  Error control gives an automatic,
per-step effective learning rate — the integrator shrinks steps in stiff
regions of the loss landscape (large curvature) and grows them on
plateaus, which is exactly the role of the WRMS-controlled step size in
the paper.  One optimizer "step" integrates pseudo-time tau.

Not meant to beat AdamW at scale — it demonstrates that the integrator
stack composes with sharded LM training states unchanged (the vector
layer is pytree-agnostic, so a 100M-param pytree is just another
N_Vector).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import arkode, butcher
from repro.core.arkode import ODEOptions


class GradFlowConfig(NamedTuple):
    tau: float = 1.0          # pseudo-time horizon per optimizer step
    rtol: float = 1e-3
    atol: float = 1e-6
    table: str = "heun_euler"  # embedded 2(1) pair: 2 grads per attempt
    max_steps: int = 20


def step(loss_fn: Callable, params, cfg: GradFlowConfig = GradFlowConfig()):
    """One gradient-flow step: integrate dtheta/dt = -grad L over tau.

    loss_fn: params -> scalar (batch already bound).
    Returns (new_params, stats) where stats is the integrator's.
    """
    grad = jax.grad(lambda p: loss_fn(p).astype(jnp.float32))

    def rhs(t, p):
        g = grad(p)
        return jax.tree_util.tree_map(lambda x: -x.astype(jnp.float32), g)

    table = butcher.ERK_TABLES[cfg.table]
    p32 = jax.tree_util.tree_map(lambda x: x.astype(jnp.float32), params)
    y, stats = arkode.erk_integrate(
        rhs, p32, 0.0, cfg.tau, table,
        ODEOptions(rtol=cfg.rtol, atol=cfg.atol, max_steps=cfg.max_steps))
    new_params = jax.tree_util.tree_map(
        lambda x, ref: x.astype(ref.dtype), y, params)
    return new_params, stats
