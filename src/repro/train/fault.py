"""Fault tolerance & elasticity bookkeeping (pure logic; host-side).

At 1000+ nodes the runtime must (a) notice dead/slow workers, (b) decide
a recovery plan, (c) rebuild the mesh and resume from the newest
committed checkpoint.  JAX's SPMD model makes (c) a restart-with-new-mesh
(processes re-enter ``jax.distributed.initialize`` with the survivor
set); this module supplies the decision logic, which is what we can
implement and test without hardware:

* :class:`HeartbeatMonitor` — per-worker heartbeats with timeout -> dead
  set, plus step-time statistics -> straggler set (z-score rule, the
  standard mitigation trigger for backup-task scheduling);
* :func:`plan_elastic_mesh` — given the survivor count and the
  parallelism constraints (model axis must stay intact for TP; data axis
  shrinks in whole multiples), returns the largest legal mesh and the
  batch resharding plan;
* :func:`should_checkpoint` — risk-based checkpoint cadence (expected
  lost work vs write cost; Young/Daly interval).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Set, Tuple


@dataclasses.dataclass
class WorkerStats:
    last_heartbeat: float = 0.0
    step_times: List[float] = dataclasses.field(default_factory=list)

    def record_step(self, t: float, window: int = 50):
        self.step_times.append(t)
        if len(self.step_times) > window:
            self.step_times.pop(0)

    @property
    def mean(self) -> float:
        return sum(self.step_times) / max(len(self.step_times), 1)


class HeartbeatMonitor:
    def __init__(self, n_workers: int, timeout_s: float = 60.0,
                 straggler_zscore: float = 3.0):
        self.timeout = timeout_s
        self.z = straggler_zscore
        self.workers: Dict[int, WorkerStats] = {
            i: WorkerStats() for i in range(n_workers)}

    def heartbeat(self, worker: int, now: Optional[float] = None):
        self.workers[worker].last_heartbeat = now or time.time()

    def record_step(self, worker: int, step_time: float):
        self.workers[worker].record_step(step_time)

    def dead(self, now: Optional[float] = None) -> Set[int]:
        now = now or time.time()
        return {w for w, s in self.workers.items()
                if s.last_heartbeat and now - s.last_heartbeat > self.timeout}

    def stragglers(self, ratio: float = 1.5) -> Set[int]:
        """Workers whose mean step time exceeds ratio x the fleet median —
        the standard backup-task trigger (robust to the straggler itself
        polluting the statistics, unlike a z-score over the mean)."""
        means = sorted(s.mean for s in self.workers.values()
                       if s.step_times)
        if len(means) < 4:
            return set()
        med = means[len(means) // 2]
        return {w for w, s in self.workers.items()
                if s.step_times and s.mean > ratio * med}


def plan_elastic_mesh(n_alive_hosts: int, chips_per_host: int,
                      model_parallel: int,
                      prefer_pods: int = 1) -> Optional[Tuple[int, ...]]:
    """Largest legal (pod, data, model) mesh on the survivors.

    TP ('model') cannot shrink without resharding weights, so it is held
    fixed; data parallelism absorbs the loss.  Returns None if fewer than
    one model group survives.
    """
    chips = n_alive_hosts * chips_per_host
    groups = chips // model_parallel
    if groups < 1:
        return None
    pods = math.gcd(prefer_pods, groups) or 1
    data = groups // pods
    return (pods, data, model_parallel)


def reshard_batch_plan(global_batch: int, old_data: int, new_data: int):
    """Keep global batch: per-replica batch grows by old/new (must stay
    integral; otherwise shrink global batch to the nearest multiple)."""
    if global_batch % new_data == 0:
        return {"global_batch": global_batch,
                "per_replica": global_batch // new_data}
    gb = (global_batch // new_data) * new_data
    return {"global_batch": gb, "per_replica": gb // new_data}


def should_checkpoint(step: int, steps_since_ckpt: int, mean_step_s: float,
                      ckpt_write_s: float, mtbf_s: float = 24 * 3600.0):
    """Young/Daly-style optimal interval: sqrt(2 * write_cost * MTBF)."""
    interval_s = math.sqrt(2.0 * ckpt_write_s * mtbf_s)
    return steps_since_ckpt * mean_step_s >= interval_s
