"""Training step: loss -> grad -> (accumulate) -> AdamW, fully jittable.

``make_train_step`` builds the canonical production step:
  * optional microbatch gradient accumulation via lax.scan (keeps the
    per-microbatch peak activation memory constant);
  * grads/loss in f32, params in cfg.dtype (bf16);
  * state donation so XLA reuses parameter/moment buffers in place.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.transformer import Model, ParallelCtx
from repro.optim import adamw


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState


def init_state(model: Model, key, ocfg: adamw.AdamWConfig = adamw.AdamWConfig()):
    params = model.init(key)
    return TrainState(params=params, opt=adamw.init(params, ocfg))


def make_train_step(model: Model, pctx: ParallelCtx = ParallelCtx(),
                    ocfg: adamw.AdamWConfig = adamw.AdamWConfig(),
                    microbatches: int = 1, grad_shardings=None):
    """Returns train_step(state, batch) -> (state, metrics).

    ``grad_shardings``: optional tree of NamedShardings (the parameter
    shardings).  Constraining grads to them makes GSPMD emit
    reduce-scatters into the sharded optimizer state instead of full
    all-reduces — half the gradient-sync traffic (§Perf 'grad-rs').
    """

    def loss_fn(params, batch):
        return model.loss(params, batch, pctx)

    def compute_grads(params, batch):
        if microbatches <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        # split leading batch dim into microbatches and accumulate
        def reshape(x):
            b = x.shape[0]
            assert b % microbatches == 0
            return x.reshape((microbatches, b // microbatches) + x.shape[1:])

        mb = jax.tree_util.tree_map(reshape, batch)

        def acc_step(carry, mbatch):
            loss_acc, g_acc = carry
            loss, g = jax.value_and_grad(loss_fn)(params, mbatch)
            g_acc = jax.tree_util.tree_map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g)
            return (loss_acc + loss, g_acc), None

        g0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, g_sum), _ = lax.scan(acc_step, (jnp.zeros(()), g0), mb)
        inv = 1.0 / microbatches
        return loss_sum * inv, jax.tree_util.tree_map(
            lambda g: g * inv, g_sum)

    def train_step(state: TrainState, batch):
        loss, grads = compute_grads(state.params, batch)
        if grad_shardings is not None:
            grads = jax.tree_util.tree_map(
                lambda g, s: jax.lax.with_sharding_constraint(g, s),
                grads, grad_shardings)
        new_params, new_opt, ostats = adamw.update(grads, state.opt,
                                                   state.params, ocfg)
        metrics = {"loss": loss.astype(jnp.float32), **ostats}
        return TrainState(new_params, new_opt), metrics

    return train_step


def abstract_state(model: Model, ocfg: adamw.AdamWConfig = adamw.AdamWConfig()):
    """ShapeDtypeStruct TrainState (for the dry-run: no allocation)."""
    aparams = model.abstract_params()
    zeros_like = lambda p: jax.ShapeDtypeStruct(p.shape, ocfg.moment_dtype)
    return TrainState(
        params=aparams,
        opt=adamw.AdamWState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            m=jax.tree_util.tree_map(zeros_like, aparams),
            v=jax.tree_util.tree_map(zeros_like, aparams)))


def state_axes(model: Model):
    """Logical-axes tree matching abstract_state (opt follows params)."""
    paxes = model.param_axes()
    return TrainState(
        params=paxes,
        opt=adamw.AdamWState(step=(), m=paxes, v=paxes))
