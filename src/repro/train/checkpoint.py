"""Fault-tolerant checkpointing: atomic, sharded, resumable.

Design for 1000+ nodes (DESIGN.md §6):
  * every process writes ONLY its addressable shards (here: one process,
    the structure is process-indexed so multi-host simply fans out);
  * writes go to ``step_<N>.tmp/`` and are renamed to ``step_<N>/``
    atomically — a crashed writer never corrupts the latest checkpoint;
  * ``latest_step`` scans for complete checkpoints only (rename is the
    commit point), so restart-after-failure always finds a good one;
  * leaves are stored as .npy keyed by the flattened pytree path;
    metadata (step, tree structure hash, process count) in meta.json.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

# numpy cannot round-trip ml_dtypes (bfloat16 etc.) through .npy reliably;
# store such leaves as raw bit patterns and view them back on load.
_BITCAST = {
    np.dtype(ml_dtypes.bfloat16): np.uint16,
}


def _to_savable(arr: np.ndarray):
    if arr.dtype in _BITCAST:
        return arr.view(_BITCAST[arr.dtype]), str(arr.dtype)
    return arr, str(arr.dtype)


def _from_saved(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    want = np.dtype(dtype_str) if dtype_str != "bfloat16" else \
        np.dtype(ml_dtypes.bfloat16)
    if want in _BITCAST and arr.dtype == _BITCAST[want]:
        return arr.view(want)
    return arr.astype(want)


def _leaf_key(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "__".join(parts) or "leaf"


def _tree_fingerprint(tree) -> str:
    keys = [ _leaf_key(p) + ":" + str(l.shape) + ":" + str(l.dtype)
             for p, l in jax.tree_util.tree_leaves_with_path(tree)]
    return hashlib.sha256("|".join(keys).encode()).hexdigest()[:16]


def save(tree: Any, ckpt_dir: str, step: int,
         process_index: int = 0) -> str:
    """Atomic save of (this process's view of) the pytree."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + f".tmp{process_index}"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    dtypes = {}
    for path, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        savable, dtype_str = _to_savable(arr)
        key = _leaf_key(path)
        dtypes[key] = dtype_str
        np.save(os.path.join(tmp, key + ".npy"), savable)
    meta = {"step": step, "fingerprint": _tree_fingerprint(tree),
            "n_leaves": len(leaves), "process_index": process_index,
            "dtypes": dtypes}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    # commit point
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Largest committed (fully renamed) checkpoint step, else None."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "meta.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore(abstract_tree: Any, ckpt_dir: str, step: int,
            shardings: Any = None) -> Any:
    """Load into the abstract tree's structure; verify fingerprint."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(final, "meta.json")) as f:
        meta = json.load(f)
    fp = _tree_fingerprint(abstract_tree)
    if meta["fingerprint"] != fp:
        raise ValueError(
            f"checkpoint fingerprint {meta['fingerprint']} != expected {fp}"
            " — model/optimizer structure changed since save")
    paths = jax.tree_util.tree_leaves_with_path(abstract_tree)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(paths))
    vals = []
    for (path, leaf), shd in zip(paths, shard_leaves):
        key = _leaf_key(path)
        arr = np.load(os.path.join(final, key + ".npy"))
        arr = _from_saved(arr, meta["dtypes"][key])
        if arr.dtype != np.dtype(leaf.dtype):
            arr = np.asarray(arr, dtype=leaf.dtype)
        if shd is not None:
            vals.append(jax.device_put(arr, shd))
        else:
            vals.append(jax.numpy.asarray(arr))
    treedef = jax.tree_util.tree_structure(abstract_tree)
    return jax.tree_util.tree_unflatten(treedef, vals)


def prune(ckpt_dir: str, keep: int = 3):
    """Delete all but the newest ``keep`` committed checkpoints."""
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(m.group(1)) for name in os.listdir(ckpt_dir)
        if (m := re.fullmatch(r"step_(\d+)", name)))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
