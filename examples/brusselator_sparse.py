"""Sparse ensemble Brusselator: dense vs sparse-direct vs preconditioned
Krylov on a banded-Jacobian ensemble (the ECP many-small-systems
workload, arXiv:2405.01713).

Each ensemble member is a 1-D Brusselator reaction-diffusion system
(n = 2*nx species-interleaved unknowns, banded Jacobian: 2x2 reaction
blocks + Laplacian neighbor coupling, fill ~ 4/nx).  Three pluggable
linear solvers integrate the SAME problem through the unified
front-end:

* ``BlockDiagGJ``        — dense batched Gauss-Jordan (O(n^2) storage)
* ``EnsembleSparseGJ``   — batched sparse LU on the shared pattern
                           (symbolic once, O(nnz) storage — the
                           SUNLINSOL_CUSOLVERSP_BATCHQR analog)
* ``SPGMR + BlockJacobi``— matrix-free GMRES, left block-Jacobi
                           preconditioning through PSetup/PSolve

Run:  PYTHONPATH=src python examples/brusselator_sparse.py
      [--nsys 64] [--nx 16] [--tf 2.0] [--pallas]
"""
import argparse
import time

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.core.context import Context
from repro.core.ivp import IVP, integrate
from repro.core.linsol import SPGMR, BlockDiagGJ, EnsembleSparseGJ
from repro.core.policies import ExecPolicy, XLA_FUSED
from repro.core.precond import BlockJacobiPrecond
from repro.core.problems import ensemble_brusselator


def run(label, prob, tf, ctx, opts, lin_solver):
    t0 = time.time()
    sol = integrate(prob, 0.0, tf, "ensemble_bdf", ctx=ctx, opts=opts,
                    lin_solver=lin_solver)
    jax.block_until_ready(sol.y)
    wall = time.time() - t0
    st = sol.stats
    nps = 0 if sol.npsolves is None else int(sol.npsolves)
    print(f"  {label:22s}: steps(med)={int(np.median(st.steps)):5d} "
          f"nni={int(sol.nni):7d} nli={int(sol.nli or 0):7d} "
          f"npsolves={nps:7d} nsetups={int(jnp.sum(st.nsetups)):6d} "
          f"ws={sol.workspace_bytes:9d}B "
          f"ok={bool(sol.success)!s:5s} wall={wall:6.2f}s")
    return sol, wall


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nsys", type=int, default=64)
    ap.add_argument("--nx", type=int, default=16)
    ap.add_argument("--tf", type=float, default=2.0)
    ap.add_argument("--rtol", type=float, default=1e-6)
    ap.add_argument("--pallas", action="store_true",
                    help="dispatch the kernels as Pallas (interpret)")
    args = ap.parse_args()

    f, jac, pattern, y0 = ensemble_brusselator(args.nsys, args.nx)
    n = 2 * args.nx
    fill = pattern.sum() / (n * n)
    print(f"ensemble brusselator: nsys={args.nsys}, n={n} "
          f"(nnz={int(pattern.sum())}, fill={100 * fill:.1f}%), "
          f"tf={args.tf}")

    prob = IVP(f=f, jac=jac, jac_sparsity=pattern, y0=y0)
    policy = (ExecPolicy(backend="pallas", interpret=True) if args.pallas
              else XLA_FUSED)
    ctx = Context(policy=policy)
    opts = ctx.options(rtol=args.rtol, atol=1e-9, max_steps=400_000)

    sols = {}
    sols["dense"] = run("BlockDiagGJ (dense)", prob, args.tf, ctx, opts,
                        BlockDiagGJ())
    sols["sparse"] = run("EnsembleSparseGJ", prob, args.tf, ctx, opts,
                         EnsembleSparseGJ())
    sols["krylov"] = run("SPGMR+BlockJacobi", prob, args.tf, ctx, opts,
                         SPGMR(tol=1e-10, restart=10, max_restarts=6,
                               precond=BlockJacobiPrecond(block_size=2)))

    y_ref = sols["dense"][0].y
    for k in ("sparse", "krylov"):
        d = float(jnp.max(jnp.abs(sols[k][0].y - y_ref)))
        sp = sols["dense"][1] / max(sols[k][1], 1e-9)
        print(f"  {k:7s} vs dense: max|dy|={d:.2e}, "
              f"dense/{k} wall ratio={sp:.2f}x")
    ws_d = sols["dense"][0].workspace_bytes
    ws_s = sols["sparse"][0].workspace_bytes
    print(f"  newton storage: dense O(n^2)={ws_d}B, "
          f"sparse O(nnz)={ws_s}B ({ws_s / ws_d:.2f}x)")


if __name__ == "__main__":
    main()
