"""Submodel use case (paper §2, Fig. 5): many small independent stiff
kinetics systems integrated concurrently.

On GPUs the paper bundles cell groups into CVODE instances on CUDA
streams; the TPU-native expression is ONE vectorized adaptive integrator
(masked while_loop) whose Newton step solves the Fig.-1 block-diagonal
Jacobian with the batched Gauss-Jordan / Pallas kernel.

Each system is a Robertson-like problem with per-cell rate constants
(the "large variations in stiffness" the paper warns about): per-system
adaptive steps absorb it.

Two integrators share the problem setup:

* default      — adaptive SDIRK2 ensemble (``ensemble_dirk_integrate``)
* ``--bdf``    — the CVODE-style batched BDF (``ensemble_bdf_integrate``)
                 with per-system order/step control and the lsetup/lsolve
                 block-kernel pipeline (``--lin-mode direct`` solves with
                 the GJ kernel each iteration instead of inverting once)

Run:  PYTHONPATH=src python examples/batched_kinetics.py [--cells 512]
      PYTHONPATH=src python examples/batched_kinetics.py --bdf --pallas
"""
import argparse
import time

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp

from repro.core import batched, butcher
from repro.core.arkode import ODEOptions
from repro.core.policies import ExecPolicy, XLA_FUSED
from repro.core.problems import batched_robertson


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cells", type=int, default=512)
    ap.add_argument("--tf", type=float, default=10.0)
    ap.add_argument("--pallas", action="store_true")
    ap.add_argument("--bdf", action="store_true",
                    help="use the batched adaptive-order BDF ensemble")
    ap.add_argument("--order", type=int, default=5)
    ap.add_argument("--lin-mode", choices=("setup", "direct"),
                    default="setup")
    ap.add_argument("--batch-tile", type=int, default=512,
                    help="systems per kernel program (bundle size)")
    args = ap.parse_args()

    n = args.cells
    f, jac, y0 = batched_robertson(n)
    policy = (ExecPolicy(backend="pallas", interpret=True,
                         batch_tile=args.batch_tile) if args.pallas
              else XLA_FUSED)
    opts = ODEOptions(rtol=1e-5, atol=1e-10, max_steps=100_000)
    kind = (f"BDF(1-{args.order}, {args.lin_mode})" if args.bdf
            else "SDIRK2")
    print(f"integrating {n} independent stiff kinetics systems with {kind} "
          f"(block-diagonal Jacobian: {n} blocks of 3x3) to t={args.tf}")
    t0 = time.time()
    if args.bdf:
        y, st = batched.ensemble_bdf_integrate(
            f, jac, y0, 0.0, args.tf, order=args.order, opts=opts,
            policy=policy, lin_mode=args.lin_mode)
    else:
        y, st = batched.ensemble_dirk_integrate(
            f, jac, y0, 0.0, args.tf, butcher.SDIRK2, opts, policy=policy)
    wall = time.time() - t0
    steps = jax.device_get(st.steps)
    print(f"  all converged: {bool(jnp.all(st.success))}   wall={wall:.2f}s")
    print(f"  per-system adaptive steps: min={steps.min()} "
          f"median={int(jnp.median(jnp.asarray(steps)))} max={steps.max()}"
          f"   (stiffer cells take more steps)")
    if args.bdf:
        nset = jax.device_get(st.nsetups)
        nni = jax.device_get(st.nni)
        print(f"  Newton iters (median): {int(jnp.median(jnp.asarray(nni)))}"
              f"   lsetups (median): {int(jnp.median(jnp.asarray(nset)))}"
              f"   (Jacobian reuse across steps)")
    mass = jnp.sum(y, axis=1)
    print(f"  mass conservation: max |1 - sum(y)| = "
          f"{float(jnp.max(jnp.abs(mass - 1.0))):.2e}")


if __name__ == "__main__":
    main()
