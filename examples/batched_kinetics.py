"""Submodel use case (paper §2, Fig. 5): many small independent stiff
kinetics systems integrated concurrently.

On GPUs the paper bundles cell groups into CVODE instances on CUDA
streams; the TPU-native expression is ONE vectorized adaptive integrator
(masked while_loop) whose Newton step solves the Fig.-1 block-diagonal
Jacobian with the batched Gauss-Jordan / Pallas kernel.

Each system is a Robertson-like problem with per-cell rate constants
(the "large variations in stiffness" the paper warns about): per-system
adaptive steps absorb it.

Run:  PYTHONPATH=src python examples/batched_kinetics.py [--cells 512]
"""
import argparse
import time

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp

from repro.core import batched, butcher
from repro.core.arkode import ODEOptions
from repro.core.policies import ExecPolicy, XLA_FUSED


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cells", type=int, default=512)
    ap.add_argument("--tf", type=float, default=10.0)
    ap.add_argument("--pallas", action="store_true")
    args = ap.parse_args()

    n = args.cells
    key = jax.random.PRNGKey(0)
    # per-cell stiffness: k3 spans two orders of magnitude
    k1 = 0.04 * jnp.ones((n,))
    k2 = 1e4 * (0.5 + jax.random.uniform(key, (n,)))
    k3 = 3e7 * 10.0 ** jax.random.uniform(jax.random.PRNGKey(1), (n,),
                                          minval=-1.0, maxval=1.0)

    def f(t, y):  # y: (n, 3)
        a, b, c = y[:, 0], y[:, 1], y[:, 2]
        r1 = k1 * a
        r2 = k2 * b * c
        r3 = k3 * b * b
        return jnp.stack([-r1 + r2, r1 - r2 - r3, r3], axis=1)

    def jac(t, y):
        a, b, c = y[:, 0], y[:, 1], y[:, 2]
        z = jnp.zeros_like(a)
        return jnp.stack([
            jnp.stack([-k1, k2 * c, k2 * b], axis=1),
            jnp.stack([k1, -k2 * c - 2 * k3 * b, -k2 * b], axis=1),
            jnp.stack([z, 2 * k3 * b, z], axis=1)], axis=1)

    y0 = jnp.concatenate([jnp.ones((n, 1)), jnp.zeros((n, 2))], axis=1)
    policy = (ExecPolicy(backend="pallas", interpret=True) if args.pallas
              else XLA_FUSED)
    print(f"integrating {n} independent stiff kinetics systems "
          f"(block-diagonal Jacobian: {n} blocks of 3x3) to t={args.tf}")
    t0 = time.time()
    y, st = batched.ensemble_dirk_integrate(
        f, jac, y0, 0.0, args.tf, butcher.SDIRK2,
        ODEOptions(rtol=1e-5, atol=1e-10, max_steps=100_000), policy=policy)
    wall = time.time() - t0
    steps = jax.device_get(st.steps)
    print(f"  all converged: {bool(jnp.all(st.success))}   wall={wall:.2f}s")
    print(f"  per-system adaptive steps: min={steps.min()} "
          f"median={int(jnp.median(jnp.asarray(steps)))} max={steps.max()}"
          f"   (stiffer cells take more steps)")
    mass = jnp.sum(y, axis=1)
    print(f"  mass conservation: max |1 - sum(y)| = "
          f"{float(jnp.max(jnp.abs(mass - 1.0))):.2e}")


if __name__ == "__main__":
    main()
