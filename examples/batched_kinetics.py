"""Submodel use case (paper §2, Fig. 5): many small independent stiff
kinetics systems integrated concurrently.

On GPUs the paper bundles cell groups into CVODE instances on CUDA
streams; the TPU-native expression is ONE vectorized adaptive integrator
(masked while_loop) whose Newton step solves the Fig.-1 block-diagonal
Jacobian with the batched Gauss-Jordan / Pallas kernel.

Each system is a Robertson-like problem with per-cell rate constants
(the "large variations in stiffness" the paper warns about): per-system
adaptive steps absorb it.

Everything goes through the unified front-end (``IVP`` + ``integrate``);
two method strings share the problem setup:

* default      — ``ensemble_dirk:sdirk2`` (adaptive SDIRK2 ensemble)
* ``--bdf``    — ``ensemble_bdf``, the CVODE-style batched BDF with
                 per-system order/step control and a *pluggable* linear
                 solver: ``--lin-solver setup|direct`` are the two
                 BlockDiagGJ block-kernel configurations, ``spgmr``
                 swaps in matrix-free Krylov without touching the
                 integrator (the paper's SUNLinearSolver point).

Run:  PYTHONPATH=src python examples/batched_kinetics.py [--cells 512]
      PYTHONPATH=src python examples/batched_kinetics.py --bdf --pallas
"""
import argparse
import time

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp

from repro.core.context import Context
from repro.core.ivp import IVP, integrate
from repro.core.linsol import SPGMR, BlockDiagGJ
from repro.core.policies import ExecPolicy, XLA_FUSED
from repro.core.problems import batched_robertson, batched_robertson_soa


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cells", type=int, default=512)
    ap.add_argument("--tf", type=float, default=10.0)
    ap.add_argument("--pallas", action="store_true")
    ap.add_argument("--bdf", action="store_true",
                    help="use the batched adaptive-order BDF ensemble")
    ap.add_argument("--order", type=int, default=5)
    ap.add_argument("--lin-solver", choices=("setup", "direct", "spgmr"),
                    default="setup",
                    help="ensemble-BDF linear solver: factor-once block "
                         "inverse, per-iteration block solve, or "
                         "matrix-free Krylov")
    ap.add_argument("--batch-tile", type=int, default=512,
                    help="systems per kernel program (bundle size)")
    args = ap.parse_args()

    n = args.cells
    f, jac, y0 = batched_robertson(n)
    policy = (ExecPolicy(backend="pallas", interpret=True,
                         batch_tile=args.batch_tile) if args.pallas
              else XLA_FUSED)
    ctx = Context(policy=policy)
    opts = ctx.options(rtol=1e-5, atol=1e-10, max_steps=100_000)
    lin = {"setup": BlockDiagGJ(factor_once=True),
           "direct": BlockDiagGJ(factor_once=False),
           "spgmr": SPGMR(tol=1e-9, restart=30, max_restarts=4)}[
        args.lin_solver]
    # native SoA RHS/Jacobian forms (system axis last) make the ensemble
    # Newton hot loop fully conversion-free; same bits as the AoS forms
    f_soa, jac_soa = batched_robertson_soa(n)
    prob = IVP(f=f, jac=jac, y0=y0, f_soa=f_soa, jac_soa=jac_soa)
    kind = (f"BDF(1-{args.order}, {lin.name})" if args.bdf else "SDIRK2")
    print(f"integrating {n} independent stiff kinetics systems with {kind} "
          f"(block-diagonal Jacobian: {n} blocks of 3x3) to t={args.tf}")
    t0 = time.time()
    if args.bdf:
        sol = integrate(prob, 0.0, args.tf, method="ensemble_bdf",
                        ctx=ctx, opts=opts, order=args.order,
                        lin_solver=lin)
    else:
        sol = integrate(prob, 0.0, args.tf, method="ensemble_dirk:sdirk2",
                        ctx=ctx, opts=opts)
    wall = time.time() - t0
    y, st = sol.y, sol.stats
    steps = jax.device_get(st.steps)
    print(f"  all converged: {bool(sol.success)}   wall={wall:.2f}s")
    print(f"  per-system adaptive steps: min={steps.min()} "
          f"median={int(jnp.median(jnp.asarray(steps)))} max={steps.max()}"
          f"   (stiffer cells take more steps)")
    if args.bdf:
        nset = jax.device_get(st.nsetups)
        nni = jax.device_get(st.nni)
        print(f"  Newton iters (median): {int(jnp.median(jnp.asarray(nni)))}"
              f"   lsetups (median): {int(jnp.median(jnp.asarray(nset)))}"
              f"   (Jacobian reuse across steps)")
        if sol.nli is not None and int(sol.nli) > 0:
            print(f"  Krylov inner iterations: {int(sol.nli)}")
    print(f"  solver workspace: {sol.workspace_bytes / 1024:.1f} KiB "
          f"(history + Newton blocks)")
    mass = jnp.sum(y, axis=1)
    print(f"  mass conservation: max |1 - sum(y)| = "
          f"{float(jnp.max(jnp.abs(mass - 1.0))):.2e}")


if __name__ == "__main__":
    main()
