"""The paper's §7 demonstration: 1D advection-reaction brusselator.

IMEX (ARK3(2)4L[2]SA) with the task-local Newton + batched 3x3 block
solver, vs the global Newton+GMRES configuration — the two solver
configurations of the paper's weak-scaling study.

Run:  PYTHONPATH=src python examples/brusselator.py [--nx 256] [--tf 1.0]
      [--solver task-local|global|both] [--pallas]
"""
import argparse
import time

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp

from repro.apps import brusselator as br
from repro.configs.brusselator import BrusselatorConfig
from repro.core.policies import ExecPolicy, XLA_FUSED


def run(cfg, label, policy):
    t0 = time.time()
    y, st = br.integrate(cfg, policy=policy)
    wall = time.time() - t0
    print(f"  {label:11s}: steps={int(st.steps):5d} attempts={int(st.attempts):5d} "
          f"newton={int(st.nni):6d} err_fails={int(st.netf):3d} "
          f"conv_fails={int(st.ncfn):3d} success={bool(st.success)} "
          f"wall={wall:7.2f}s")
    return y, st, wall


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nx", type=int, default=256)
    ap.add_argument("--tf", type=float, default=1.0)
    ap.add_argument("--solver", default="both",
                    choices=["task-local", "global", "both"])
    ap.add_argument("--pallas", action="store_true",
                    help="use the Pallas block-solve kernel (interpret mode)")
    args = ap.parse_args()

    policy = (ExecPolicy(backend="pallas", interpret=True) if args.pallas
              else XLA_FUSED)
    print(f"brusselator1d: nx={args.nx} (={3*args.nx} ODEs), tf={args.tf}, "
          f"eps=5e-6 (stiff)")

    results = {}
    for solver in (["task-local", "global"] if args.solver == "both"
                   else [args.solver]):
        cfg = BrusselatorConfig(nx=args.nx, t_final=args.tf, solver=solver)
        results[solver] = run(cfg, solver, policy)

    if len(results) == 2:
        ytl = results["task-local"][0]
        ygl = results["global"][0]
        diff = float(jnp.max(jnp.abs(ytl - ygl)))
        speedup = results["global"][2] / results["task-local"][2]
        print(f"  solutions agree to {diff:.2e}; task-local is "
              f"{speedup:.2f}x faster (paper: task-local >> global)")
    y = next(iter(results.values()))[0]
    print(f"  final ranges: u [{float(y[:,0].min()):.4f}, "
          f"{float(y[:,0].max()):.4f}]  w [{float(y[:,2].min()):.4f}, "
          f"{float(y[:,2].max()):.4f}]")


if __name__ == "__main__":
    main()
