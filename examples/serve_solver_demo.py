"""Serving the solver stack: dynamic batching + warm-start streaming.

Two client patterns against one :class:`repro.serve.solver.SolverServer`:

1. a mixed burst — many one-shot requests across two problem families
   (parametric Robertson kinetics n=3, linear decay chain n=6) with
   per-request physics, batched into padded bundles behind shared
   compiled traces;
2. a streaming client — one trajectory advanced leg by leg, each
   request warm-starting from the previous response's ``session``
   handle (no cold order-1 restart between legs).

Run:  PYTHONPATH=src python examples/serve_solver_demo.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core.problems import decay_chain_family, robertson_family
from repro.serve.solver import ProblemFamily, SolverServer


def main():
    fr = robertson_family()
    fd = decay_chain_family(6)
    server = SolverServer(
        [ProblemFamily("robertson", 3, fr[0], fr[1], fr[2], fr[3]),
         ProblemFamily("decay6", 6, fd[0], fd[1], fd[2], fd[3])],
        bucket_sizes=(16, 32), max_batch=32, max_wait=2e-3)

    # -- pattern 1: a mixed burst of one-shot requests ------------------
    rng = np.random.default_rng(0)
    futs = []
    with server:                                  # background pump
        for i in range(40):
            futs.append(server.submit(
                "robertson", [1.0, 0.0, 0.0], 0.0, 0.4,
                params={"k1": 0.04, "k2": 1e4 * (0.5 + rng.random()),
                        "k3": 3e7 * 10.0 ** rng.uniform(-1, 1)}))
        for i in range(20):
            futs.append(server.submit(
                "decay6", np.ones(6), 0.0, 1.0,
                params={"k": rng.uniform(0.1, 5.0, 6)}))
        sols = [f.result(timeout=120) for f in futs]

    ok = sum(bool(s.success) for s in sols)
    t = sols[0].timings
    print(f"burst: {ok}/{len(sols)} solved; first-request timings: "
          f"queue_wait={t['queue_wait'] * 1e3:.1f}ms "
          f"compile={t['compile']:.2f}s execute={t['execute'] * 1e3:.1f}ms")

    # -- pattern 2: streaming warm-start continuation -------------------
    p = {"k1": 0.04, "k2": 1.2e4, "k3": 3e7}
    sol = None
    total_steps = []
    for leg in range(4):                          # 4 legs of 0.3 each
        fut = server.submit(
            "robertson",
            [1.0, 0.0, 0.0] if sol is None else np.asarray(sol.y),
            0.0 if sol is None else float(sol.t),
            0.3 * (leg + 1), params=p,
            session=None if sol is None else sol.session)
        server.drain()
        sol = fut.result(timeout=120)
        total_steps.append(int(sol.stats.steps))
    print(f"stream: 4 legs to t={float(sol.t):.1f}, per-leg steps "
          f"{total_steps} (legs 2+ warm-start from the session handle "
          f"instead of a cold order-1 restart)")
    print(f"final state: {np.asarray(sol.y)}")

    # -- observability --------------------------------------------------
    m = server.metrics()
    cache = m["trace_cache"]
    print(f"metrics: {m['requests']} requests in {m['bundles']} bundles, "
          f"occupancy={m['occupancy']:.2f}, "
          f"p50={m['latency_p50_s'] * 1e3:.0f}ms "
          f"p99={m['latency_p99_s'] * 1e3:.0f}ms")
    print(f"trace cache: {cache['hits']} hits / {cache['misses']} misses "
          f"(hit rate {cache['hit_rate']:.2f}), "
          f"steady-state recompiles: {m['steady_misses']}")


if __name__ == "__main__":
    main()
