"""Quickstart: the two faces of `repro` in one script.

1. SUNDIALS-on-JAX: solve a stiff ODE through the unified front-end
   (`IVP` + `integrate(method=...)` -> `Solution`), swapping integration
   method and linear solver without touching the problem.
2. LM framework: train a small transformer for a few steps with AdamW,
   then with the gradient-flow (ODE) optimizer — the same integrator
   driving a parameter pytree.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro import configs
from repro.core.context import Context
from repro.core.ivp import IVP, integrate
from repro.core.linsol import DenseGJ
from repro.data import pipeline
from repro.models import Model
from repro.optim import adamw, gradflow
from repro.train import step as tstep


def ode_demo():
    print("=== 1. stiff ODE via the unified front-end (CVODE analog) ===")

    def f(t, y):  # Robertson chemical kinetics
        return jnp.stack([
            -0.04 * y[0] + 1e4 * y[1] * y[2],
            0.04 * y[0] - 1e4 * y[1] * y[2] - 3e7 * y[1] ** 2,
            3e7 * y[1] ** 2])

    ctx = Context()  # ExecPolicy + MemoryHelper + run-wide counters
    prob = IVP(f=f, y0=jnp.asarray([1.0, 0.0, 0.0]))
    sol = integrate(prob, 0.0, 40.0, method="bdf", ctx=ctx,
                    opts=ctx.options(rtol=1e-6, atol=1e-10),
                    lin_solver=DenseGJ())
    st = sol.stats
    print(f"  y(40) = {[float(v) for v in sol.y]}")
    print(f"  steps={int(st.steps)} newton_iters={int(sol.nni)} "
          f"err_fails={int(st.netf)}  mass={float(jnp.sum(sol.y)):.9f}")
    print(f"  lin_solver={sol.lin_solver}  "
          f"workspace={sol.workspace_bytes}B")


def lm_demo():
    print("=== 2. LM training (AdamW, then gradient-flow ODE optimizer) ===")
    cfg = configs.get("internlm2-1.8b-smoke")
    model = Model(cfg)
    state = tstep.init_state(model, jax.random.PRNGKey(0))
    dcfg = pipeline.DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                               global_batch=8)
    train = jax.jit(tstep.make_train_step(model))
    for i, b in zip(range(5), pipeline.batches(dcfg)):
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        state, m = train(state, batch)
        print(f"  adamw step {i}: loss={float(m['loss']):.4f}")
    batch = {k: jnp.asarray(v) for k, v in next(pipeline.batches(dcfg, 5)).items()}
    lf = lambda p: model.loss(p, batch)
    before = float(lf(state.params))
    p2, st = gradflow.step(lf, state.params,
                           gradflow.GradFlowConfig(tau=0.2, max_steps=8))
    print(f"  gradflow: {int(st.steps)} adaptive ODE steps, "
          f"loss {before:.4f} -> {float(lf(p2)):.4f}")


if __name__ == "__main__":
    ode_demo()
    lm_demo()
