"""Serving example: batched autoregressive decode with KV caches.

Uses the same serve_step the dry-run lowers for the decode shapes.
Run:  PYTHONPATH=src python examples/serve_demo.py [--arch internlm2-1.8b-smoke]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import Model
from repro.serve import decode


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b-smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    extra = None
    if cfg.enc_dec:
        extra = {"enc_out": 0.02 * jnp.ones((args.batch, 8, cfg.d_model),
                                            cfg.dtype)}
    t0 = time.time()
    out = decode.generate(model, params, prompt, args.max_new,
                          temperature=args.temperature,
                          key=jax.random.PRNGKey(2), extra_batch=extra)
    wall = time.time() - t0
    total_new = args.batch * args.max_new
    print(f"arch={cfg.name} batch={args.batch} "
          f"prompt={args.prompt_len} new={args.max_new}")
    print(f"generated {total_new} tokens in {wall:.2f}s "
          f"({total_new / wall:.1f} tok/s on CPU incl. compile)")
    for row in jax.device_get(out)[:2]:
        print("  tokens:", row.tolist())


if __name__ == "__main__":
    main()
