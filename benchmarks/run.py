"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (per the repo contract).
Modules may additionally stash a ``json_artifact = (path, payload)``
during ``run()``; the harness writes it out (e.g. ``ensemble_bench`` ->
``BENCH_ensemble.json``, the ensemble perf-trajectory artifact).

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run vector_ops # one module
  PYTHONPATH=src python -m benchmarks.run --check    # CI perf gate
  PYTHONPATH=src python -m benchmarks.run --tune     # autotune cache

``--check`` re-times every configuration recorded in the committed
``BENCH_ensemble.json`` and exits 1 if any pallas-interpret config
falls below its regression floor — 80% of the committed pallas/jnp
speedup ratio, with the committed ratio capped at 1.25 first, so in
practice the gate asserts the kernels keep BEATING the jnp oracle
rather than reproducing a noisy high-water mark (timing gates the
>=4096-system configs; smaller ones are timer-noise-bound and
informational) — or if ANY config drifts past the 1e-14 accuracy
bound.  It then applies the same discipline to every entry in the
committed autotune cache (``.autotune/interpret.json``): the recorded
jnp-vs-pallas winner must still win on re-measure
(autotune_bench.check).  It then runs the serving front-end's
functional invariants (serving_bench.check: trace-cache behavior,
occupancy, warm-start win; latency informational), and finally the
observability overhead ceilings (observability_bench.check: disabled
config <= 1.02x, telemetry+profiling <= 1.05x on the execute stage).
This is the gate the CI smoke step runs (ensemble_bench.check
documents the cap rationale).

``--tune`` regenerates the autotune cache: every OP_TABLE op is timed
on both backends over a grid of shape signatures and the measured
winners/tiles are written to ``.autotune/interpret.json`` (committed,
like the BENCH files) — the measurement store that ``backend='auto'``
dispatch resolves from.
"""
from __future__ import annotations

import json
import sys
import time

import jax

jax.config.update("jax_enable_x64", True)

MODULES = [
    "vector_ops",            # paper Fig. 3
    "meshvector_overhead",   # paper Fig. 4
    "brusselator_scaling",   # paper Figs. 7/8/9
    "linear_sum_bandwidth",  # paper Table 1
    "kernels_bench",         # kernel-path microbenchmarks
    "ensemble_bench",        # paper Fig. 5 submodel A/B -> BENCH_ensemble.json
    "sparse_bench",          # sparse-vs-dense Newton solve -> BENCH_sparse.json
    "roofline_table",        # EXPERIMENTS §Roofline (derived from dry-run)
    "serving_bench",         # dynamic-batching server -> BENCH_serving.json
    "observability_bench",   # off/on overhead -> BENCH_observability.json
]


def main() -> None:
    if "--tune" in sys.argv[1:]:
        from benchmarks import autotune_bench
        cache = autotune_bench.tune()
        print(f"tune,{len(cache.entries)},{cache.path}")
        sys.exit(0)
    if "--check" in sys.argv[1:]:
        from benchmarks import (autotune_bench, ensemble_bench,
                                observability_bench, serving_bench)
        ok = ensemble_bench.check()
        print(f"perf_check,{'PASS' if ok else 'FAIL'},BENCH_ensemble.json")
        ok_tune = autotune_bench.check()
        print(f"autotune_check,{'PASS' if ok_tune else 'FAIL'},"
              f".autotune/interpret.json")
        ok_serve = serving_bench.check()
        print(f"serving_check,{'PASS' if ok_serve else 'FAIL'},"
              f"serving invariants (latency informational)")
        ok_obs = observability_bench.check()
        print(f"observability_check,{'PASS' if ok_obs else 'FAIL'},"
              f"off<=1.02 on<=1.05 execute-stage overhead")
        sys.exit(0 if (ok and ok_tune and ok_serve and ok_obs) else 1)
    picked = sys.argv[1:] or MODULES
    print("name,us_per_call,derived")
    for name in picked:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.time()
        try:
            rows = mod.run()
        except Exception as e:  # keep the harness going
            print(f"{name}.ERROR,0,{type(e).__name__}:{e}")
            continue
        for r in rows:
            print(",".join(str(x) for x in r), flush=True)
        artifact = getattr(mod, "json_artifact", None)
        if artifact:
            path, payload = artifact
            with open(path, "w") as fh:
                json.dump(payload, fh, indent=2)
            print(f"{name}.json_artifact,0,{path}", flush=True)
        print(f"{name}.total_wall_s,{time.time()-t0:.1f},-", flush=True)


if __name__ == "__main__":
    main()
