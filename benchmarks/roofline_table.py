"""Aggregate the dry-run results into the §Roofline table (derived, not
timed): reads benchmarks/results/dryrun/*.json."""
from __future__ import annotations

import glob
import json
import os

DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def load_rows(mesh="single", tagged=False):
    rows = []
    for f in sorted(glob.glob(os.path.join(DIR, "*.json"))):
        base = os.path.basename(f)[:-5]
        if (base.count("__") != 2) != tagged:
            continue  # untagged = baseline table; tagged = perf iterations
        r = json.load(open(f))
        if r.get("skipped") or not r.get("ok") or "roofline" not in r:
            continue
        if mesh and r["mesh"] != mesh:
            continue
        rows.append(r)
    return rows


def run():
    out = []
    for r in load_rows("single"):
        rl = r["roofline"]
        dom = {"compute": rl["t_compute"], "memory": rl["t_memory"],
               "collective": rl["t_collective"]}[rl["bottleneck"]]
        out.append((f"roofline.{r['arch']}.{r['shape']}", dom * 1e6,
                    f"bneck={rl['bottleneck']},mfu_bound={rl['mfu_bound']:.4f},"
                    f"useful={rl['useful_ratio']:.2f}"))
    n_multi = len(load_rows("multi"))
    out.append(("dryrun.multi_pod_cells_ok", float(n_multi), "2x16x16"))
    return out


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
