"""Fig.-3 analog: node-local vector operation performance vs length.

Paper: serial vs CUDA/HIP/RAJA/OpenMPDEV vectors; crossover at ~1e4
elements set by the ~8us kernel-launch latency.  Here: numpy-serial vs
jit-jnp (XLA) vs Pallas(interpret excluded from timing claims — we time
the jnp backend the TPU deployment would JIT) — the crossover is set by
the XLA dispatch overhead, which we measure the same way the paper
measured launch latency (timing an empty kernel).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch as dp
from repro.core import vector as nv
from repro.core.policies import GRID_STRIDE, XLA_FUSED

LENGTHS = [10 ** 3, 10 ** 4, 10 ** 5, 10 ** 6]
REPS = 30
AB_N = 2 ** 15          # modest: pallas interpret mode is CPU-emulated
AB_REPS = 5

STREAMING = {
    "linear_sum": (lambda x, y: nv.linear_sum(2.0, x, -1.0, y),
                   lambda x, y: 2.0 * x - 1.0 * y),
    "prod": (nv.prod, lambda x, y: x * y),
    "scale": (lambda x, y: nv.scale(3.0, x), lambda x, y: 3.0 * x),
    "abs": (lambda x, y: nv.vabs(x), lambda x, y: np.abs(x)),
}
REDUCTION = {
    "dot": (nv.dot, lambda x, y: np.dot(x, y)),
    "wrms": (lambda x, y: nv.wrms_norm(x, y),
             lambda x, y: np.sqrt(np.mean((x * y) ** 2))),
    "max_norm": (lambda x, y: nv.max_norm(x), lambda x, y: np.abs(x).max()),
    "l1_norm": (lambda x, y: nv.l1_norm(x), lambda x, y: np.abs(x).sum()),
}


def _time(fn, *args, reps=REPS):
    fn(*args)  # warm
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn(*args)
    if hasattr(r, "block_until_ready"):
        r.block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6  # us


def ab_table(n: int = AB_N):
    """jnp-vs-pallas(interpret) A/B through the dispatch layer.

    Paper Fig. 3 analog: per-op time for the two ExecPolicy backends.
    On this CPU host the pallas numbers are interpret-mode (correctness
    path, not a perf claim — TPU perf comes from the same entry points
    with interpret=False); the table's value is (a) both backends run the
    identical dispatch call sites and (b) the jnp column is the real
    XLA-fused cost the deployment pays.
    """
    rows = []
    key = jax.random.PRNGKey(0)
    for K in range(2, 9):
        vecs = [jax.random.normal(jax.random.PRNGKey(i), (n,))
                for i in range(K)]
        coeffs = [1.0 / (i + 1) for i in range(K)]
        t_j = _time(lambda: jax.block_until_ready(
            dp.linear_combination(coeffs, vecs, XLA_FUSED)), reps=AB_REPS)
        t_p = _time(lambda: jax.block_until_ready(
            dp.linear_combination(coeffs, vecs, GRID_STRIDE)), reps=AB_REPS)
        rows.append((f"ab.linear_combination.K{K}.n{n}.jnp_us", t_j,
                     f"pallas_interpret_us={t_p:.1f}"))
    x = jax.random.normal(key, (n,))
    w = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (n,))) + 0.1
    t_j = _time(lambda: jax.block_until_ready(
        dp.wrms_norm(x, w, XLA_FUSED)), reps=AB_REPS)
    t_p = _time(lambda: jax.block_until_ready(
        dp.wrms_norm(x, w, GRID_STRIDE)), reps=AB_REPS)
    rows.append((f"ab.wrms_norm.n{n}.jnp_us", t_j,
                 f"pallas_interpret_us={t_p:.1f}"))
    return rows


def run():
    rows = []
    # dispatch overhead (paper's empty-kernel launch-latency measurement)
    empty = jax.jit(lambda x: x)
    x0 = jnp.zeros((8,))
    overhead = _time(lambda: empty(x0).block_until_ready(), reps=200)
    rows.append(("dispatch_overhead_us", overhead, "paper_analog=8us_launch"))

    for n in LENGTHS:
        xj = jnp.arange(n, dtype=jnp.float64) / n
        yj = jnp.ones((n,), jnp.float64) * 0.5
        xn, yn = np.asarray(xj), np.asarray(yj)
        for fam, table in (("stream", STREAMING), ("reduce", REDUCTION)):
            for name, (jfn, nfn) in table.items():
                jitted = jax.jit(jfn)
                t_jax = _time(lambda: jax.block_until_ready(jitted(xj, yj)))
                t_np = _time(nfn, xn, yn)
                rows.append((f"{fam}.{name}.n{n}.jnp", t_jax,
                             f"numpy_us={t_np:.2f}"))
    # crossover estimate for linear_sum
    jitted = jax.jit(STREAMING["linear_sum"][0])
    for n in LENGTHS:
        xj = jnp.zeros((n,)); yj = jnp.ones((n,))
        t_jax = _time(lambda: jax.block_until_ready(jitted(xj, yj)))
        t_np = _time(STREAMING["linear_sum"][1], np.zeros(n), np.ones(n))
        if t_jax <= t_np:
            rows.append(("crossover_linear_sum", float(n),
                         "first_n_where_jit_wins"))
            break
    rows.extend(ab_table())
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
