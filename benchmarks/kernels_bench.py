"""Kernel-path microbenchmarks: batched block solve & fused vecops.

Times the pure-jnp (XLA) implementations — the performance-relevant
backend on this host — and runs the Pallas kernels in interpret mode for
a correctness spot-check under benchmark shapes (their TPU performance
is modeled in EXPERIMENTS.md §Perf from BlockSpec arithmetic).

``--smoke`` runs the fast jnp-vs-pallas(interpret) A/B check over every
dispatched vector op (the CI gate): both backends are invoked through
the repro.core.dispatch table and must agree to tolerance, and every op
is additionally run under ``backend='auto'`` (the autotune-cache /
cost-model resolver) against the jnp oracle.  It also sweeps the
unified front-end: one ``repro.core.ivp.integrate`` call per canonical
method string under the jnp, pallas-interpret, AND auto policies,
asserting success (so a regression in any method family or in the
policy plumbing fails CI before the full suite runs).
"""
from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import direct, matrix
from repro.kernels import ops, ref


def _t(fn, *a, reps=20):
    jax.block_until_ready(fn(*a))
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn(*a)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    for nb, b in ((1024, 3), (8192, 3), (4096, 8)):
        A = jax.random.normal(key, (nb, b, b)) + (b + 2.0) * jnp.eye(b)
        r = jax.random.normal(jax.random.PRNGKey(1), (nb, b))
        gj = jax.jit(direct.gauss_jordan_batched)
        t_gj = _t(gj, A, r)
        lu = jax.jit(lambda A, r: direct.block_lu_solve(
            direct.block_lu_factor(matrix.BlockDiagMatrix(A)), r, b))
        t_lu = _t(lu, A, r)
        x = ops.block_solve(A, r, batch_tile=128)   # pallas interpret check
        err = float(jnp.max(jnp.abs(x - ref.block_solve_ref(A, r))))
        rows.append((f"block_solve.nb{nb}.b{b}.gj_xla", t_gj,
                     f"lu_us={t_lu:.1f},pallas_interp_err={err:.1e}"))
    for K, N in ((5, 2 ** 20),):
        c = jnp.arange(1.0, K + 1)
        X = jax.random.normal(key, (K, N))
        fused = jax.jit(lambda c, X: jnp.einsum("k,kn->n", c, X))
        pairwise = jax.jit(lambda c, X: sum(c[i] * X[i] for i in range(K)))
        rows.append((f"lincomb.K{K}.N{N}.fused", _t(fused, c, X),
                     f"pairwise_us={_t(pairwise, c, X):.1f}"))
    return rows


def smoke(n: int = 4096, tol: float = 1e-5):
    """Fast dispatch-layer A/B: every op, jnp vs pallas-interpret AND
    jnp vs backend='auto' (cache/cost-model-resolved per call site),
    with a per-op timing row.  Exits nonzero on any mismatch (CI
    gate)."""
    from repro.core import dispatch as dp
    from repro.core import vector as nv
    from repro.core.policies import AUTO, GRID_STRIDE, XLA_FUSED

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (n,))
    y = jax.random.normal(jax.random.PRNGKey(1), (n,))
    z = jax.random.normal(jax.random.PRNGKey(2), (n,))
    w = jnp.abs(y) + 0.1
    m = (x > 0).astype(x.dtype)
    coeffs = [0.3, -1.2, 2.5]
    # ensemble block ops: a deliberately non-multiple-of-128 batch so the
    # gate also covers the bundle-tile padding path
    nb, bs = 516, 3
    Ab = jax.random.normal(jax.random.PRNGKey(3), (bs, bs, nb)) + \
        (bs + 2.0) * jnp.eye(bs)[:, :, None]
    rb = jax.random.normal(jax.random.PRNGKey(4), (bs, nb))
    # row-tiled GJ regime (b > 8) under the same ragged batch
    bt = 16
    At = jax.random.normal(jax.random.PRNGKey(9), (bt, bt, nb)) + \
        (bt + 2.0) * jnp.eye(bt)[:, :, None]
    rt = jax.random.normal(jax.random.PRNGKey(10), (bt, nb))
    # fused ensemble-Newton op operands (SoA (n, nsys), ragged batch)
    gmb = jnp.abs(jax.random.normal(jax.random.PRNGKey(11), (nb,)))
    wb = jnp.abs(jax.random.normal(jax.random.PRNGKey(12), (bs, nb))) + 0.1
    mb = jax.random.uniform(jax.random.PRNGKey(13), (nb,)) > 0.4
    q1 = 6
    Wh = jax.random.normal(jax.random.PRNGKey(14), (q1, q1, nb))
    Zh = jax.random.normal(jax.random.PRNGKey(15), (q1, bs, nb))
    # sparse ops: a banded CSR pattern (non-lane-multiple rows) and a
    # shared block pattern with a ragged system batch
    ncsr = 133
    pat_el = np.abs(np.arange(ncsr)[:, None] - np.arange(ncsr)) <= 2
    from repro.core.sunmatrix import SparseCSR
    csr = SparseCSR.from_dense(
        np.asarray(jax.random.normal(jax.random.PRNGKey(5),
                                     (ncsr, ncsr))) * pat_el)
    xs = jax.random.normal(jax.random.PRNGKey(6), (ncsr,))
    nblk, bb, nbs = 5, 3, 130
    brows, bcols = zip(*[(i, j) for i in range(nblk)
                         for j in range(nblk) if abs(i - j) <= 1])
    bpat = (tuple(brows), tuple(bcols), nblk)
    Vb = jax.random.normal(jax.random.PRNGKey(7),
                           (len(brows), bb, bb, nbs)) + \
        jnp.where((jnp.asarray(brows) == jnp.asarray(bcols))
                  [:, None, None, None],
                  (bb + 2.0) * jnp.eye(bb)[None, :, :, None], 0.0)
    xb = jax.random.normal(jax.random.PRNGKey(8), (nblk, bb, nbs))
    cases = {
        "linear_sum": lambda p: dp.linear_sum(2.0, x, -0.5, y, p),
        "linear_combination": lambda p: dp.linear_combination(
            coeffs, [x, y, z], p),
        "scale_add_multi": lambda p: jnp.stack(
            dp.scale_add_multi(coeffs, x, [x, y, z], p)),
        "axpy": lambda p: dp.axpy(1.7, x, y, p),
        "dot": lambda p: dp.dot(x, y, p),
        "wrms_norm": lambda p: dp.wrms_norm(x, w, p),
        "wrms_norm_mask": lambda p: dp.wrms_norm_mask(x, w, m, p),
        "dot_prod_multi": lambda p: dp.dot_prod_multi(x, [y, z, w], p),
        "block_solve_soa": lambda p: dp.block_solve_soa(Ab, rb, p),
        "block_inverse_soa": lambda p: dp.block_inverse_soa(Ab, p),
        "blockdiag_spmv_soa": lambda p: dp.blockdiag_spmv_soa(Ab, rb, p),
        "block_solve_soa.b16": lambda p: dp.block_solve_soa(At, rt, p),
        "block_inverse_soa.b16": lambda p: dp.block_inverse_soa(At, p),
        "newton_residual_soa": lambda p: dp.newton_residual_soa(
            rb, wb, rb, gmb, p, negate=True),
        "masked_update_wrms_soa": lambda p: jnp.concatenate(
            [x.ravel() for x in dp.masked_update_wrms_soa(rb, rb, wb,
                                                          mb, p)]),
        "history_rescale_soa": lambda p: dp.history_rescale_soa(
            Wh, Zh, mb, p),
        "wrms_soa": lambda p: dp.wrms_soa(rb, wb, p),
        "csr_spmv": lambda p: dp.csr_spmv(csr.data, xs, csr.pattern, p),
        "bsr_spmv_soa": lambda p: dp.bsr_spmv_soa(Vb, xb, bpat, p),
        "bsr_block_jacobi_inverse_soa":
            lambda p: dp.bsr_block_jacobi_inverse_soa(Vb, bpat, p),
    }
    rows, ok = [], True
    for name, fn in cases.items():
        a = np.asarray(fn(XLA_FUSED))
        t0 = time.perf_counter()
        b = np.asarray(fn(GRID_STRIDE))
        t_p = (time.perf_counter() - t0) * 1e6
        err = float(np.max(np.abs(a - b)))
        good = err <= tol
        ok &= good
        rows.append((f"smoke.{name}", "PASS" if good else "FAIL",
                     f"maxerr={err:.2e},pallas_us={t_p:.0f}"))
        # auto backend: whatever the cache/model resolves must agree too
        c = np.asarray(fn(AUTO))
        err_a = float(np.max(np.abs(a - c)))
        good_a = err_a <= tol
        ok &= good_a
        rows.append((f"smoke.auto.{name}", "PASS" if good_a else "FAIL",
                     f"maxerr={err_a:.2e}"))
    return rows, ok


def frontend_smoke():
    """One `integrate` call per canonical method string, under both the
    jnp and the pallas-interpret ExecPolicy.  Small problems, loose
    tolerances — this gates wiring, not accuracy."""
    import jax.numpy as jnp

    from repro.core.arkode import ODEOptions
    from repro.core.context import Context
    from repro.core.ivp import IVP, METHOD_STRINGS, integrate
    from repro.core.policies import AUTO, GRID_STRIDE, XLA_FUSED

    lam = 12.0
    f1 = lambda t, y: -lam * (y - jnp.cos(t))
    fe1 = lambda t, y: lam * jnp.cos(t) * jnp.ones_like(y)
    fi1 = lambda t, y: -lam * y
    nsys, n = 4, 3
    rates = jnp.linspace(2.0, lam, nsys)
    fb = lambda t, y: -rates[:, None] * (y - jnp.cos(t)[:, None])
    jb = lambda t, y: jnp.broadcast_to(
        -rates[:, None, None] * jnp.eye(n), (y.shape[0], n, n))

    scalar = IVP(f=f1, y0=jnp.zeros((2,)))
    imex = IVP(fe=fe1, fi=fi1, y0=jnp.zeros((2,)))
    ens = IVP(f=fb, jac=jb, y0=jnp.zeros((nsys, n)))

    rows, ok = [], True
    for pname, pol in (("jnp", XLA_FUSED), ("pallas", GRID_STRIDE),
                       ("auto", AUTO)):
        ctx = Context(policy=pol)
        opts = ctx.options(rtol=1e-4, atol=1e-7, max_steps=20_000)
        for m in METHOD_STRINGS:
            prob = imex if m.startswith("imex") else \
                ens if m.startswith("ensemble") else scalar
            t0 = time.perf_counter()
            sol = integrate(prob, 0.0, 1.0, m, ctx=ctx, opts=opts)
            us = (time.perf_counter() - t0) * 1e6
            good = bool(sol.success) and bool(
                jnp.all(jnp.isfinite(jnp.asarray(sol.y))))
            ok &= good
            rows.append((f"frontend.{pname}.{m}",
                         "PASS" if good else "FAIL",
                         f"nni={int(sol.nni)},ws={sol.workspace_bytes}B,"
                         f"us={us:.0f}"))
    return rows, ok


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        rows, ok = smoke()
        fr_rows, fr_ok = frontend_smoke()
        for r in rows + fr_rows:
            print(",".join(str(x) for x in r))
        sys.exit(0 if (ok and fr_ok) else 1)
    for r in run():
        print(",".join(str(x) for x in r))
