"""Kernel-path microbenchmarks: batched block solve & fused vecops.

Times the pure-jnp (XLA) implementations — the performance-relevant
backend on this host — and runs the Pallas kernels in interpret mode for
a correctness spot-check under benchmark shapes (their TPU performance
is modeled in EXPERIMENTS.md §Perf from BlockSpec arithmetic).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import direct, matrix
from repro.kernels import ops, ref


def _t(fn, *a, reps=20):
    jax.block_until_ready(fn(*a))
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn(*a)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    for nb, b in ((1024, 3), (8192, 3), (4096, 8)):
        A = jax.random.normal(key, (nb, b, b)) + (b + 2.0) * jnp.eye(b)
        r = jax.random.normal(jax.random.PRNGKey(1), (nb, b))
        gj = jax.jit(direct.gauss_jordan_batched)
        t_gj = _t(gj, A, r)
        lu = jax.jit(lambda A, r: direct.block_lu_solve(
            direct.block_lu_factor(matrix.BlockDiagMatrix(A)), r, b))
        t_lu = _t(lu, A, r)
        x = ops.block_solve(A, r, batch_tile=128)   # pallas interpret check
        err = float(jnp.max(jnp.abs(x - ref.block_solve_ref(A, r))))
        rows.append((f"block_solve.nb{nb}.b{b}.gj_xla", t_gj,
                     f"lu_us={t_lu:.1f},pallas_interp_err={err:.1e}"))
    for K, N in ((5, 2 ** 20),):
        c = jnp.arange(1.0, K + 1)
        X = jax.random.normal(key, (K, N))
        fused = jax.jit(lambda c, X: jnp.einsum("k,kn->n", c, X))
        pairwise = jax.jit(lambda c, X: sum(c[i] * X[i] for i in range(K)))
        rows.append((f"lincomb.K{K}.N{N}.fused", _t(fused, c, X),
                     f"pairwise_us={_t(pairwise, c, X):.1f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
