"""Fig.-4 analog: MeshVector (MPIPlusX) overhead vs raw operations.

Paper: MPIPlusX-with-serial vs the monolithic MPI-parallel vector —
overhead negligible.  Here: MeshVector-wrapped ops vs raw jnp ops, both
jitted; the wrapper must trace away completely (the virtual dispatch is
a trace-time construct), so the ratio should be ~1.0.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import vector as nv

LENGTHS = [10 ** 4, 10 ** 5, 10 ** 6]
REPS = 50


def _time(fn, *args):
    fn(*args)
    t0 = time.perf_counter()
    for _ in range(REPS):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / REPS * 1e6


def run():
    rows = []
    for n in LENGTHS:
        x = jnp.arange(n, dtype=jnp.float64)
        w = jnp.full((n,), 0.5)

        @jax.jit
        def raw_stream(x, w):
            return 2.0 * x - 3.0 * w

        @jax.jit
        def mv_stream(x, w):
            mx, mw = nv.MeshVector(x), nv.MeshVector(w)
            return mx.linear_sum(2.0, -3.0, mw).data

        @jax.jit
        def raw_reduce(x, w):
            return jnp.sqrt(jnp.mean((x * w) ** 2))

        @jax.jit
        def mv_reduce(x, w):
            return nv.MeshVector(x).wrms_norm(nv.MeshVector(w))

        ts_raw = _time(raw_stream, x, w)
        ts_mv = _time(mv_stream, x, w)
        tr_raw = _time(raw_reduce, x, w)
        tr_mv = _time(mv_reduce, x, w)
        rows.append((f"stream.n{n}.meshvector", ts_mv,
                     f"raw_us={ts_raw:.2f},ratio={ts_mv/ts_raw:.3f}"))
        rows.append((f"reduce.n{n}.meshvector", tr_mv,
                     f"raw_us={tr_raw:.2f},ratio={tr_mv/tr_raw:.3f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
