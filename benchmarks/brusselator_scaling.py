"""Figs. 7/8/9 analog: brusselator scaling study, task-local vs global.

Paper: weak scaling on Summit, task-local+CUDA 3.7-4.9x over serial,
global scales worse than task-local; Fig. 9 breaks time into advection /
reaction / linear-solve / other.  On one CPU we (a) scale nx, (b) compare
the two solver configurations, (c) produce the Fig.-9 region breakdown
by timing the operators standalone at matched call counts.
"""
from __future__ import annotations

import time

import jax

from repro.apps import brusselator as br
from repro.configs.brusselator import BrusselatorConfig

SIZES = [64, 256, 1024]
TF = 0.25


def _wall(fn):
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(out[0] if isinstance(out, tuple) else out)
    return out, time.perf_counter() - t0


def run():
    rows = []
    breakdown_cfg = None
    for nx in SIZES:
        stats = {}
        for solver in ("task-local", "global"):
            cfg = BrusselatorConfig(nx=nx, t_final=TF, solver=solver)
            (y, st), wall = _wall(lambda c=cfg: br.integrate(c))
            # exclude compile: run again
            (y, st), wall2 = _wall(lambda c=cfg: br.integrate(c))
            stats[solver] = (wall2, st)
            rows.append((f"brusselator.nx{nx}.{solver}", wall2 * 1e6,
                         f"steps={int(st.steps)},newton={int(st.nni)},"
                         f"netf={int(st.netf)}"))
        sp = stats["global"][0] / stats["task-local"][0]
        rows.append((f"brusselator.nx{nx}.speedup_tasklocal_vs_global",
                     sp, "paper_fig8_analog"))
        breakdown_cfg = BrusselatorConfig(nx=SIZES[-1], t_final=TF)

    # Fig. 9 region breakdown at the largest size (per-call us, x calls)
    cfg = breakdown_cfg
    y0 = br.initial_state(cfg)
    fe = jax.jit(br.advection_rhs(cfg))
    fi = jax.jit(br.reaction_rhs(cfg))
    lin = br.task_local_lin_solver(cfg)
    jlin = jax.jit(lambda z, rhs: lin(0.0, z, 1e-4, rhs))
    _, st = br.integrate(cfg)
    n_stage = 4 * int(st.attempts)
    n_newton = int(st.nni)

    def t_of(f, *a):
        jax.block_until_ready(f(*a))
        t0 = time.perf_counter()
        for _ in range(20):
            r = f(*a)
        jax.block_until_ready(r)
        return (time.perf_counter() - t0) / 20

    t_adv = t_of(fe, 0.0, y0) * n_stage
    t_rea = t_of(fi, 0.0, y0) * (n_stage + n_newton)
    t_lin = t_of(jlin, y0, y0) * n_newton
    total = max(stats["task-local"][0], 1e-9)
    other = max(total - t_adv - t_rea - t_lin, 0.0)
    for name, val in (("advection", t_adv), ("reaction", t_rea),
                      ("linear_solve", t_lin), ("other", other)):
        rows.append((f"brusselator.breakdown.{name}", val * 1e6,
                     f"frac={val/total:.2f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
