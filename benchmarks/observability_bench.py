"""Observability overhead benchmark: the zero/low-cost contract, timed.

The observability subsystem promises two ceilings on the batched-BDF
hot path (the ``BENCH_ensemble`` end-to-end workload):

* **off** — a constructed-but-disabled ``ObservabilityConfig`` on the
  context adds <= 2% to execution: structurally it adds *zero
  equations* (the ``telemetry-purity`` sunlint rule checks the jaxprs
  are identical), so anything measured here is host-side dispatch
  noise;
* **on** — step telemetry (the in-loop ring-buffer carry) plus region
  profiling adds <= 5%: one ``.at[idx % K].set`` scatter per field per
  step attempt, amortized over the Newton solves.

Execution time is isolated through the ``timed=True`` AOT path of
``IVP.integrate`` — the ``timings["execute"]`` stage is a pure run of
the compiled program, so the ratios compare device work, not trace or
compile time (each timed call re-lowers; compile cost is reported
separately as INFO).  The table lands in ``BENCH_observability.json``
via the ``json_artifact`` contract of ``benchmarks/run.py``.

``check()`` is the ``--check`` gate hook: both ratios gate CI at the
>= 4096-system configs (best-of-``REPEATS``, one retry), the smaller
config is informational — same timer-noise rationale as
``ensemble_bench.GATE_MIN_NSYS``.  ``REPRO_PERF_CHECK=info`` demotes
timing failures to informational, same escape hatch as the other perf
gates.
"""
from __future__ import annotations

import numpy as np

import jax

from repro.core.context import Context
from repro.core.ivp import IVP, integrate

CONFIGS = (
    # (nsys, tf, telemetry capacity) — the BENCH_ensemble end-to-end
    # kinetics workload at three ensemble sizes; tf shrinks as nsys
    # grows so every point stays seconds-scale while execution stays
    # well above timer granularity
    (512, 2.0, 1024),
    (4096, 0.5, 512),
    (32768, 0.02, 128),
)
REPEATS = 3
OFF_CEILING = 1.02
ON_CEILING = 1.05
GATE_MIN_NSYS = 4096

# module-global artifact picked up by benchmarks/run.py after run()
json_artifact = None


def _problem(nsys):
    from repro.core.problems import batched_robertson, batched_robertson_soa
    f, jac, y0 = batched_robertson(nsys)
    f_soa, jac_soa = batched_robertson_soa(nsys)
    return IVP(f=f, jac=jac, f_soa=f_soa, jac_soa=jac_soa, y0=y0)


def _best_execute(prob, tf, repeats=REPEATS, **kw):
    """Best-of-``repeats`` ``timings["execute"]`` (and the last full
    Solution, for correctness checks)."""
    best, sol = float("inf"), None
    compile_s = 0.0
    for _ in range(repeats):
        sol = integrate(prob, 0.0, tf, "ensemble_bdf", timed=True, **kw)
        best = min(best, sol.timings["execute"])
        compile_s = sol.timings["compile"]
    return best, compile_s, sol


def _measure(nsys, tf, capacity, repeats=REPEATS) -> dict:
    from repro.observability import ObservabilityConfig
    prob = _problem(nsys)
    base_s, base_c, base_sol = _best_execute(prob, tf, repeats)
    # disabled-but-constructed config: the structural-zero-cost claim
    off_ctx = Context(observability=ObservabilityConfig())
    off_s, _, off_sol = _best_execute(prob, tf, repeats, ctx=off_ctx)
    # telemetry ring in the carry + profiler regions around the stages
    on_ctx = Context(observability=ObservabilityConfig(
        profile=True, profile_sync=False, telemetry=True,
        telemetry_capacity=capacity))
    on_s, on_c, on_sol = _best_execute(prob, tf, repeats, ctx=on_ctx)
    # observability must never perturb the solution
    assert np.array_equal(np.asarray(base_sol.y), np.asarray(off_sol.y))
    assert np.array_equal(np.asarray(base_sol.y), np.asarray(on_sol.y))
    assert on_sol.telemetry is not None
    steps = int(np.sum(np.asarray(on_sol.stats.steps)))
    return {"nsys": nsys, "tf": tf, "telemetry_capacity": capacity,
            "steps_total": steps,
            "base_execute_s": base_s, "off_execute_s": off_s,
            "on_execute_s": on_s,
            "off_ratio": off_s / base_s, "on_ratio": on_s / base_s,
            "base_compile_s": base_c, "on_compile_s": on_c,
            "telemetry_truncated": bool(on_sol.telemetry.truncated)}


def run():
    global json_artifact
    rows = []
    table = {"workload": "ensemble_bdf robertson kinetics, observability "
                         "off/on execute-stage overhead",
             "ceilings": {"off": OFF_CEILING, "on": ON_CEILING},
             "note": ("ratios compare timed=True AOT execute stages "
                      "(best-of-%d); compile reported separately"
                      % REPEATS),
             "results": []}
    for nsys, tf, cap in CONFIGS:
        res = _measure(nsys, tf, cap)
        table["results"].append(res)
        rows.append((f"observability.off.n{nsys}",
                     1e6 * res["off_execute_s"],
                     f"ratio={res['off_ratio']:.3f},"
                     f"base_s={res['base_execute_s']:.4f}"))
        rows.append((f"observability.on.n{nsys}",
                     1e6 * res["on_execute_s"],
                     f"ratio={res['on_ratio']:.3f},"
                     f"steps={res['steps_total']},cap={cap},"
                     f"compile_s={res['on_compile_s']:.2f}"))
    json_artifact = ("BENCH_observability.json", table)
    return rows


def check() -> bool:
    """``benchmarks/run.py --check`` hook: off <= 1.02, on <= 1.05 on
    the execute stage, gating at >= GATE_MIN_NSYS systems (one retry
    per failing config; ``REPRO_PERF_CHECK=info`` demotes to INFO)."""
    import os
    soft = os.environ.get("REPRO_PERF_CHECK", "").lower() == "info"
    ok = True
    for nsys, tf, cap in CONFIGS:
        gating = nsys >= GATE_MIN_NSYS and not soft
        good = False
        for attempt in range(2):
            res = _measure(nsys, tf, cap)
            good = (res["off_ratio"] <= OFF_CEILING and
                    res["on_ratio"] <= ON_CEILING)
            if good or not gating:
                break
        ok &= (good or not gating)
        verdict = ("PASS" if good else "FAIL") if gating else "INFO"
        print(f"check.observability.n{nsys},{verdict},"
              f"off_ratio={res['off_ratio']:.3f}(<= {OFF_CEILING}),"
              f"on_ratio={res['on_ratio']:.3f}(<= {ON_CEILING})",
              flush=True)
    return ok


if __name__ == "__main__":
    import json
    jax.config.update("jax_enable_x64", True)
    for row in run():
        print(",".join(str(x) for x in row))
    if json_artifact:
        path, payload = json_artifact
        with open(path, "w") as fh:
            json.dump(payload, fh, indent=2)
        print(f"wrote {path}")
