"""Ensemble-subsystem benchmark: the jnp-vs-pallas A/B for the batched
block-diagonal Newton pipeline (paper Fig. 5 submodel workload).

Measures systems/sec for the batched block solve across ensemble sizes
and block sizes, on both dispatch backends:

* 'jnp'    — gauss_jordan_batched (XLA batched; the performance-relevant
             backend on this CPU host);
* 'pallas' — the SoA GJ kernel in interpret mode (CPU emulation: its
             numbers here validate correctness and relative scaling only
             — TPU performance is modeled in EXPERIMENTS.md from
             BlockSpec arithmetic).

``run()`` also stashes the A/B table as ``json_artifact`` so
``benchmarks/run.py`` can emit ``BENCH_ensemble.json`` (the perf
trajectory artifact), and times one full ``ensemble_bdf_integrate``
call for an end-to-end row.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import dispatch as dv
from repro.core.policies import ExecPolicy, XLA_FUSED

NSYS = (512, 4096, 32768)
BLOCKS = (3, 8, 16)

# module-global artifact picked up by benchmarks/run.py after run()
json_artifact = None


def _newton_blocks(key, b, nsys, dtype=jnp.float64):
    """Diagonally-dominant SoA Newton-like blocks M = I - gamma*J."""
    J = jax.random.normal(key, (b, b, nsys), dtype)
    return jnp.eye(b, dtype=dtype)[:, :, None] - 0.05 * J


def _time(fn, *a, reps=5):
    jax.block_until_ready(fn(*a))
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn(*a)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / reps


def run():
    global json_artifact
    rows = []
    table = {"workload": "batched block solve (M x = r, SoA layout)",
             "units": "systems_per_sec",
             "note": ("pallas timings are interpret-mode CPU emulation "
                      "(correctness/scaling A/B, not TPU perf)"),
             "results": []}
    key = jax.random.PRNGKey(0)
    for b in BLOCKS:
        for nsys in NSYS:
            A = _newton_blocks(key, b, nsys)
            r = jax.random.normal(jax.random.PRNGKey(1), (b, nsys),
                                  A.dtype)
            # one program per bundle: whole batch in a single grid step
            pol = ExecPolicy(backend="pallas", interpret=True,
                             batch_tile=nsys)
            f_jnp = jax.jit(lambda A, r: dv.block_solve_soa(A, r,
                                                            XLA_FUSED))
            f_pal = jax.jit(lambda A, r: dv.block_solve_soa(A, r, pol))
            t_jnp = _time(f_jnp, A, r)
            t_pal = _time(f_pal, A, r, reps=2)
            err = float(jnp.max(jnp.abs(f_jnp(A, r) - f_pal(A, r))))
            table["results"].append({
                "block_size": b, "nsys": nsys,
                "jnp_systems_per_sec": nsys / t_jnp,
                "pallas_interpret_systems_per_sec": nsys / t_pal,
                "max_abs_diff": err})
            rows.append((f"ensemble.block_solve.b{b}.n{nsys}.jnp",
                         t_jnp * 1e6,
                         f"sys_per_s={nsys / t_jnp:.3e},"
                         f"pallas_us={t_pal * 1e6:.0f},err={err:.1e}"))
    rows.append(_integrate_row())
    json_artifact = ("BENCH_ensemble.json", table)
    return rows


def _integrate_row(nsys: int = 512, tf: float = 10.0):
    """End-to-end batched-BDF kinetics row (jnp backend)."""
    from repro.core import batched
    from repro.core.arkode import ODEOptions
    from repro.core.problems import batched_robertson

    f, jac, y0 = batched_robertson(nsys)
    opts = ODEOptions(rtol=1e-5, atol=1e-10, max_steps=100_000)
    t0 = time.perf_counter()
    y, st = batched.ensemble_bdf_integrate(f, jac, y0, 0.0, tf, opts=opts)
    jax.block_until_ready(y)
    wall = time.perf_counter() - t0
    ok = bool(jnp.all(st.success))
    return (f"ensemble.bdf_kinetics.n{nsys}", wall * 1e6,
            f"sys_per_s={nsys / wall:.3e},converged={ok}")


if __name__ == "__main__":
    import json
    jax.config.update("jax_enable_x64", True)
    for row in run():
        print(",".join(str(x) for x in row))
    if json_artifact:
        path, payload = json_artifact
        with open(path, "w") as fh:
            json.dump(payload, fh, indent=2)
        print(f"wrote {path}")
