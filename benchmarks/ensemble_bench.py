"""Ensemble-subsystem benchmark: the jnp-vs-pallas A/B for the batched
block-diagonal Newton pipeline (paper Fig. 5 submodel workload).

Measures systems/sec for the batched block solve across ensemble sizes
and block sizes (b=3 chemistry blocks up to b=24, the row-tiled-GJ
regime), on both dispatch backends:

* 'jnp'    — gauss_jordan_batched (XLA batched; the performance-relevant
             backend on this CPU host);
* 'pallas' — the SoA GJ kernels in interpret mode (CPU emulation: its
             numbers here validate correctness and relative scaling only
             — TPU performance is modeled in EXPERIMENTS.md from
             BlockSpec arithmetic).  b <= 8 runs the fully-unrolled
             kernel, b >= 16 the row-tiled elimination.

``run()`` also stashes the A/B table as ``json_artifact`` so
``benchmarks/run.py`` can emit ``BENCH_ensemble.json`` (the perf
trajectory artifact), and times one full ``ensemble_bdf_integrate``
call for an end-to-end row.

``check()`` is the CI regression gate (``benchmarks/run.py --check``):
it re-times every configuration in the committed JSON and fails if any
pallas-interpret config regresses more than 20% — compared on the
pallas/jnp speedup RATIO, which is machine-independent (absolute
systems/sec would gate on the CI runner's clock, not on the kernels),
or if the kernel-vs-oracle ``max_abs_diff`` exceeds 1e-14.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import dispatch as dv
from repro.core.policies import ExecPolicy, XLA_FUSED

NSYS = (512, 4096, 32768)
BLOCKS = (3, 8, 16, 24)
DIFF_TOL = 1e-14
REGRESSION_SLACK = 0.8     # fresh ratio >= 0.8 * capped committed ratio
RATIO_CAP = 1.25           # committed ratio is capped here before the
# slack is applied: interpret-mode timings on a shared host jitter by
# 2-3x, so the gate anchors on the stable property the kernels must
# keep — BEATING the jnp oracle (0.8 * 1.25 = parity floor for every
# config whose committed speedup is comfortable) — instead of flaking
# on a noisy high-water mark.  The b=16 regression this PR fixed
# (0.62x) fails this gate; a 3.0x -> 2.0x noise swing does not.
GATE_MIN_NSYS = 4096       # configs below this run in O(100us) where
# the per-call dispatch overhead and timer granularity dominate and the
# measured ratio swings ~4x run-to-run even best-of-20; they are still
# measured and printed (INFO) but only the >=4096-system configs —
# which include both acceptance rows (b=16, nsys 4096/32768) — gate CI.

# module-global artifact picked up by benchmarks/run.py after run()
json_artifact = None


def _newton_blocks(key, b, nsys, dtype=jnp.float64):
    """Diagonally-dominant SoA Newton-like blocks M = I - gamma*J."""
    J = jax.random.normal(key, (b, b, nsys), dtype)
    return jnp.eye(b, dtype=dtype)[:, :, None] - 0.05 * J


def _time(fn, *a, reps=5):
    """Best-of-reps wall time: each rep timed (and synced) separately,
    MIN taken — the noise-robust statistic for a shared/loaded host
    (a mean is polluted by load spikes, which made a 20% regression
    gate on mean-based ratios flake by 3x run to run)."""
    jax.block_until_ready(fn(*a))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*a))
        best = min(best, time.perf_counter() - t0)
    return best


def _measure(b: int, nsys: int, reps=None):
    """One config's jnp/pallas systems-per-sec + kernel-vs-oracle diff.
    Small batches run in O(100us), so they get more reps for the
    best-of-reps timer to stabilize."""
    if reps is None:
        reps = (20, 10) if nsys <= 1024 else (5, 2)
    key = jax.random.PRNGKey(0)
    A = _newton_blocks(key, b, nsys)
    r = jax.random.normal(jax.random.PRNGKey(1), (b, nsys), A.dtype)
    # one program per bundle: whole batch in a single grid step
    pol = ExecPolicy(backend="pallas", interpret=True, batch_tile=nsys)
    f_jnp = jax.jit(lambda A, r: dv.block_solve_soa(A, r, XLA_FUSED))
    f_pal = jax.jit(lambda A, r: dv.block_solve_soa(A, r, pol))
    t_jnp = _time(f_jnp, A, r, reps=reps[0])
    t_pal = _time(f_pal, A, r, reps=reps[1])
    err = float(jnp.max(jnp.abs(f_jnp(A, r) - f_pal(A, r))))
    return {"block_size": b, "nsys": nsys,
            "jnp_systems_per_sec": nsys / t_jnp,
            "pallas_interpret_systems_per_sec": nsys / t_pal,
            "max_abs_diff": err}


def run():
    global json_artifact
    rows = []
    table = {"workload": "batched block solve (M x = r, SoA layout)",
             "units": "systems_per_sec",
             "note": ("pallas timings are interpret-mode CPU emulation "
                      "(correctness/scaling A/B, not TPU perf); "
                      "b<=8 = unrolled GJ kernel, b>=16 = row-tiled GJ"),
             "results": []}
    for b in BLOCKS:
        for nsys in NSYS:
            res = _measure(b, nsys)
            table["results"].append(res)
            t_jnp = nsys / res["jnp_systems_per_sec"]
            t_pal = nsys / res["pallas_interpret_systems_per_sec"]
            rows.append((f"ensemble.block_solve.b{b}.n{nsys}.jnp",
                         t_jnp * 1e6,
                         f"sys_per_s={nsys / t_jnp:.3e},"
                         f"pallas_us={t_pal * 1e6:.0f},"
                         f"err={res['max_abs_diff']:.1e}"))
    rows.append(_integrate_row())
    json_artifact = ("BENCH_ensemble.json", table)
    return rows


def check(path: str = "BENCH_ensemble.json") -> bool:
    """CI gate: re-time every committed config; fail on a pallas
    timing regression below the floor (80% of the committed pallas/jnp
    ratio, capped at RATIO_CAP — see the constants above) or on a
    kernel-vs-oracle drift above 1e-14.  A failing config is re-measured
    once before it counts (interpret-mode timings on shared CI runners
    are noisy; a genuine kernel regression fails both attempts).

    ``REPRO_PERF_CHECK=info`` in the environment demotes TIMING
    failures to informational (accuracy still gates): the ratio is
    ultimately a host property (emulation overhead vs XLA CPU codegen),
    so a runner-generation or XLA upgrade can shift it systematically —
    the toggle keeps CI unblocked while BENCH_ensemble.json is
    regenerated on the new baseline."""
    import json
    import os
    soft = os.environ.get("REPRO_PERF_CHECK", "").lower() == "info"
    with open(path) as fh:
        committed = json.load(fh)
    ok = True
    for ref in committed["results"]:
        b, nsys = ref["block_size"], ref["nsys"]
        ref_ratio = (ref["pallas_interpret_systems_per_sec"] /
                     ref["jnp_systems_per_sec"])
        floor = REGRESSION_SLACK * min(ref_ratio, RATIO_CAP)
        gating = nsys >= GATE_MIN_NSYS and not soft
        good = False
        for attempt in range(2):
            res = _measure(b, nsys)
            ratio = (res["pallas_interpret_systems_per_sec"] /
                     res["jnp_systems_per_sec"])
            # accuracy drift gates at EVERY size; the timing ratio only
            # for >= GATE_MIN_NSYS configs (see the constant's
            # rationale) — so an informational config's noisy ratio
            # neither fails the gate nor triggers the retry
            good = (res["max_abs_diff"] <= DIFF_TOL and
                    (not gating or ratio >= floor))
            if good:
                break
        ok &= good
        verdict = "FAIL" if not good else ("PASS" if gating else "INFO")
        print(f"check.ensemble.b{b}.n{nsys},{verdict},"
              f"ratio={ratio:.2f},committed={ref_ratio:.2f},"
              f"floor={floor:.2f},"
              f"err={res['max_abs_diff']:.1e}", flush=True)
    return ok


def _integrate_row(nsys: int = 512, tf: float = 10.0):
    """End-to-end batched-BDF kinetics row (jnp backend, native SoA
    RHS/Jacobian — the conversion-free hot loop)."""
    from repro.core import batched
    from repro.core.arkode import ODEOptions
    from repro.core.problems import batched_robertson, batched_robertson_soa

    f, jac, y0 = batched_robertson(nsys)
    f_soa, jac_soa = batched_robertson_soa(nsys)
    opts = ODEOptions(rtol=1e-5, atol=1e-10, max_steps=100_000)
    t0 = time.perf_counter()
    y, st = batched.ensemble_bdf_integrate(f, jac, y0, 0.0, tf, opts=opts,
                                           f_soa=f_soa, jac_soa=jac_soa)
    jax.block_until_ready(y)
    wall = time.perf_counter() - t0
    ok = bool(jnp.all(st.success))
    return (f"ensemble.bdf_kinetics.n{nsys}", wall * 1e6,
            f"sys_per_s={nsys / wall:.3e},converged={ok}")


if __name__ == "__main__":
    import json
    jax.config.update("jax_enable_x64", True)
    for row in run():
        print(",".join(str(x) for x in row))
    if json_artifact:
        path, payload = json_artifact
        with open(path, "w") as fh:
            json.dump(payload, fh, indent=2)
        print(f"wrote {path}")
