"""Serving front-end benchmark: throughput/latency of the dynamic-
batching IVP server (``repro.serve.solver``) under load.

Drives a :class:`~repro.serve.solver.server.SolverServer` with
mixed-shape kinetics traffic (parametric Robertson n=3 + linear decay
chain n=6 — distinct buckets, so the trace cache is exercised across
families) at three load points per backend and reports per-point
p50/p99 latency, systems/sec, and batch occupancy.  The table lands in
``BENCH_serving.json`` via the ``json_artifact`` contract of
``benchmarks/run.py``.

Backends: ``jnp`` (XLA-fused dispatch, the performance-relevant CPU
path) at real load; ``pallas-interpret`` at reduced counts/horizons
(interpret mode is a correctness emulation — its rows validate that the
serving stack composes with the kernel backend, not TPU performance).

``smoke()`` is the CI acceptance run (``--smoke``): >= 10^4 mixed-shape
requests through one server, asserting the serving invariants —
trace-cache hit rate >= 95% with ZERO steady-state recompiles after the
warmup window, batch occupancy >= 80%, warm-start continuations taking
strictly fewer steps than a cold restart of the same leg, and a short
pallas-interpret burst solving successfully.  It then validates the
observability surface: the Prometheus text exposition must parse and
reconcile with ``metrics()``, and a profiled mini-run must produce a
Chrome-trace/Perfetto timeline carrying queue-wait / compile / execute
spans for EVERY flushed bundle.

``check()`` is the ``--check`` gate hook: a scaled-down smoke whose
functional invariants (hit rate / steady misses / occupancy /
warm-start win) gate CI deterministically; latency/throughput rows are
always informational (they are host properties, per the
REPRO_PERF_CHECK rationale in ensemble_bench).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.context import Context
from repro.core.policies import ExecPolicy, XLA_FUSED
from repro.core.problems import decay_chain_family, robertson_family
from repro.serve.solver import ProblemFamily, RetryAfter, SolverServer

LOAD_POINTS_JNP = (256, 1024, 4096)       # requests per load point
LOAD_POINTS_PALLAS = (8, 16, 32)          # interpret mode: emulation cost
TF_JNP = 0.4
TF_PALLAS = 0.02
SMOKE_REQUESTS = 10_240                   # >= 10^4 acceptance floor
SMOKE_HIT_RATE = 0.95
SMOKE_OCCUPANCY = 0.80

# module-global artifact picked up by benchmarks/run.py after run()
json_artifact = None


def _families():
    fr = robertson_family()
    fd = decay_chain_family(6)
    return (ProblemFamily("robertson", 3, fr[0], fr[1], fr[2], fr[3]),
            ProblemFamily("decay6", 6, fd[0], fd[1], fd[2], fd[3]))


def _make_server(policy: ExecPolicy, bucket_sizes, max_batch,
                 max_wait: float = 1e-3, max_depth: int = 4096
                 ) -> SolverServer:
    # warmup window: a saturated poll drains one family's full chunk
    # run before touching the next bucket, so the second family's
    # first-touch compile can land ~max_depth/(2*max_batch) bundles in
    return SolverServer(list(_families()), Context(policy=policy),
                        bucket_sizes=bucket_sizes, max_batch=max_batch,
                        max_wait=max_wait, max_depth=max_depth,
                        warmup_bundles=max(16, max_depth // max_batch))


def _submit_mixed(srv: SolverServer, nreq: int, tf: float, seed: int,
                  decay_every: int = 2):
    """Submit ``nreq`` mixed-family requests with per-request physics,
    pumping the server whenever admission pushes back."""
    rng = np.random.default_rng(seed)
    futs = []
    for i in range(nreq):
        if decay_every and i % decay_every == 1:
            kw = dict(family="decay6", y0=np.ones(6), t0=0.0, tf=tf,
                      params={"k": rng.uniform(0.1, 5.0, 6)})
        else:
            kw = dict(family="robertson", y0=[1.0, 0.0, 0.0], t0=0.0,
                      tf=tf,
                      params={"k1": 0.04,
                              "k2": 1e4 * (0.5 + rng.random()),
                              "k3": 3e7 * 10.0 ** rng.uniform(-1, 1)})
        while True:
            try:
                futs.append(srv.submit(**kw))
                break
            except RetryAfter:
                srv.pump()          # backpressure: drain, then retry
    return futs


def _load_point(srv: SolverServer, nreq: int, tf: float, seed: int,
                decay_every: int = 2) -> dict:
    """One measured point: submit ``nreq`` requests open-loop, drain,
    report wall clock, percentiles, and occupancy over the point."""
    m0 = srv.metrics()
    srv.take_latencies()
    t0 = time.perf_counter()
    futs = _submit_mixed(srv, nreq, tf, seed, decay_every)
    srv.drain()
    wall = time.perf_counter() - t0
    ok = all(bool(f.result().success) for f in futs)
    lat = sorted(srv.take_latencies())
    m1 = srv.metrics()
    live = m1["live_lanes"] - m0["live_lanes"]
    padded = m1["padded_lanes"] - m0["padded_lanes"]
    q = SolverServer._quantile
    return {"requests": nreq, "wall_s": wall,
            "systems_per_sec": nreq / wall,
            "latency_p50_ms": 1e3 * q(lat, 0.50),
            "latency_p99_ms": 1e3 * q(lat, 0.99),
            "occupancy": (live / padded) if padded else 0.0,
            "all_success": ok}


def run():
    global json_artifact
    rows = []
    table = {"workload": "dynamic-batching IVP serving "
                         "(robertson n=3 + decay chain n=6)",
             "units": "systems_per_sec / latency_ms",
             "note": ("pallas rows are interpret-mode CPU emulation "
                      "(stack-composition check, not TPU perf); load "
                      "points are open-loop request counts per backend"),
             "backends": {}}
    configs = (
        # (name, policy, load points, tf, bucket sizes, max_batch,
        #  decay_every) — pallas runs robertson-only (decay_every=0):
        # interpret-mode compiles are minutes-scale, one trace is enough
        # for the composition check
        ("jnp", XLA_FUSED, LOAD_POINTS_JNP, TF_JNP, (32, 64, 128), 128, 2),
        ("pallas_interpret",
         ExecPolicy(backend="pallas", interpret=True),
         LOAD_POINTS_PALLAS, TF_PALLAS, (8,), 8, 0),
    )
    for name, policy, points, tf, sizes, max_batch, mix in configs:
        srv = _make_server(policy, sizes, max_batch)
        # warmup: populate the trace cache so load points measure
        # steady-state serving, not first-touch compiles
        warm = _submit_mixed(srv, 2 * max_batch, tf, seed=0,
                             decay_every=mix)
        srv.drain()
        [f.result() for f in warm]
        entries = []
        for i, nreq in enumerate(points):
            res = _load_point(srv, nreq, tf, seed=i + 1, decay_every=mix)
            entries.append(res)
            rows.append((f"serving.{name}.n{nreq}",
                         1e6 * res["wall_s"] / nreq,
                         f"sys_per_s={res['systems_per_sec']:.3e},"
                         f"p50_ms={res['latency_p50_ms']:.2f},"
                         f"p99_ms={res['latency_p99_ms']:.2f},"
                         f"occ={res['occupancy']:.2f}"))
        m = srv.metrics()
        table["backends"][name] = {
            "load_points": entries,
            "trace_cache": m["trace_cache"],
            "steady_misses": m["steady_misses"],
            "occupancy_cumulative": m["occupancy"]}
    json_artifact = ("BENCH_serving.json", table)
    return rows


def _validate_prometheus(text: str, m: dict) -> None:
    """The scrape must be well-formed text exposition AND reconcile
    with the dict ``metrics()`` reports."""
    lines = [ln for ln in text.splitlines() if ln]
    assert lines, "empty Prometheus exposition"
    seen_types = {}
    for ln in lines:
        if ln.startswith("# TYPE "):
            _, _, name, kind = ln.split(" ", 3)
            seen_types[name] = kind
        else:
            assert ln.startswith("#") or " " in ln, f"malformed: {ln!r}"
    assert seen_types.get("repro_serve_requests_total") == "counter"
    assert seen_types.get("repro_serve_latency_seconds") == "histogram"
    assert seen_types.get("repro_serve_occupancy") == "gauge"
    assert f"repro_serve_requests_total {m['requests']}" in text
    assert f"repro_serve_bundles_total {m['bundles']}" in text
    assert ("repro_serve_latency_seconds_count "
            f"{m['latency_observed']}") in text
    assert 'repro_serve_latency_seconds_bucket' in text
    assert 'le="+Inf"' in text
    # failure-path counters reconcile with metrics() (zero on a clean
    # run; the chaos suite exercises the nonzero side)
    assert (f"repro_serve_degraded_total {m['degraded']}") in text
    for reason, count in m["failures"].items():
        assert (f'repro_serve_failures_total{{reason="{reason}"}} '
                f"{count}") in text
    # the Context counters ride the same scrape
    assert "repro_context_integrations_total" in text


def _profiled_trace_smoke(nreq: int = 96, verbose: bool = True) -> None:
    """A profiled mini-run: every flushed bundle must land queue-wait /
    compile / execute spans on the profiler timeline, and the exported
    Chrome trace must be loadable, well-formed JSON."""
    import json as _json
    import os
    import tempfile

    from repro.observability import ObservabilityConfig

    fr = robertson_family()
    ctx = Context(observability=ObservabilityConfig(
        profile=True, profile_sync=False))
    srv = SolverServer(
        [ProblemFamily("robertson", 3, fr[0], fr[1], fr[2], fr[3])],
        ctx=ctx, bucket_sizes=(32,), max_batch=32, max_wait=1e-3,
        warmup_bundles=0)
    futs = _submit_mixed(srv, nreq, TF_JNP, seed=23, decay_every=0)
    bundles = srv.drain()
    assert all(bool(f.result().success) for f in futs)
    spans = {}
    for s in srv.ctx.profiler.spans:
        spans.setdefault(s.name, []).append(s)
    for name in ("serve.bundle.queue_wait", "serve.bundle.compile",
                 "serve.bundle.execute"):
        got = len(spans.get(name, ()))
        assert got == bundles, \
            f"{name}: {got} spans for {bundles} flushed bundles"
    fd, path = tempfile.mkstemp(suffix=".json")
    os.close(fd)
    try:
        srv.ctx.profiler.export_chrome_trace(path)
        with open(path) as fh:
            doc = _json.load(fh)
        ev = doc["traceEvents"]
        assert all(e["ph"] == "X" and e["dur"] >= 0 and e["ts"] >= 0
                   for e in ev)
        per_bundle = [e for e in ev
                      if e["name"].startswith("serve.bundle.")]
        assert len(per_bundle) == 3 * bundles
    finally:
        os.unlink(path)
    if verbose:
        print(f"serving.perfetto,{bundles},spans_per_bundle=3,"
              f"trace_events={len(ev)}", flush=True)


def smoke(nreq: int = SMOKE_REQUESTS, verbose: bool = True,
          hit_rate_floor: float = SMOKE_HIT_RATE) -> dict:
    """The CI acceptance run: >= 10^4 mixed-shape requests through one
    jnp-backed server, then the serving invariants are ASSERTED (not
    just printed).  Returns the final metrics dict.

    ``hit_rate_floor`` defaults to the 95% acceptance bar, which is a
    statement about the >= 10^4-request run (2 cold compiles amortized
    over ~80 bundles); scaled-down runs must scale it too (check()
    does) — steady_misses == 0 is the scale-free invariant either way.
    """
    srv = _make_server(XLA_FUSED, bucket_sizes=(128,), max_batch=128)
    futs = _submit_mixed(srv, nreq, TF_JNP, seed=7)
    srv.drain()
    sols = [f.result() for f in futs]
    assert all(bool(s.success) for s in sols), "some requests failed"
    m = srv.metrics()
    cache = m["trace_cache"]
    assert cache["hit_rate"] >= hit_rate_floor, \
        f"trace-cache hit rate {cache['hit_rate']:.3f} < {hit_rate_floor}"
    assert m["steady_misses"] == 0, \
        f"{m['steady_misses']} steady-state recompiles (want 0)"
    assert m["occupancy"] >= SMOKE_OCCUPANCY, \
        f"occupancy {m['occupancy']:.2f} < {SMOKE_OCCUPANCY}"

    # warm-start win: continue one robertson trajectory via its session
    # handle vs a cold restart of the SAME leg (same bundle, same
    # trace).  The leg keeps the ORIGINAL request's rate constants —
    # the session's Nordsieck history describes THAT chemistry; a
    # continuation under different params is a valid but history-
    # mismatched restart with no step-count guarantee.
    p = {"k1": 0.04, "k2": 1.2e4, "k3": 3e7}
    f0 = srv.submit("robertson", [1.0, 0.0, 0.0], 0.0, TF_JNP, params=p)
    srv.drain()
    s = f0.result()
    leg = dict(family="robertson", y0=np.asarray(s.y), t0=float(s.t),
               tf=float(s.t) + TF_JNP, params=p)
    f_warm = srv.submit(**leg, session=s.session)
    f_cold = srv.submit(**leg)
    srv.drain()
    warm_steps = int(f_warm.result().stats.steps)
    cold_steps = int(f_cold.result().stats.steps)
    assert warm_steps < cold_steps, \
        f"warm-start took {warm_steps} steps vs cold {cold_steps}"

    # pallas-interpret burst: the serving stack composes with the
    # kernel backend (emulation-mode, so tiny horizon and bundle)
    psrv = _make_server(ExecPolicy(backend="pallas", interpret=True),
                        bucket_sizes=(8,), max_batch=8)
    pfuts = _submit_mixed(psrv, 8, TF_PALLAS, seed=11, decay_every=0)
    psrv.drain()
    assert all(bool(f.result().success) for f in pfuts), \
        "pallas-interpret burst failed"

    # observability surface: the Prometheus scrape must reconcile with
    # metrics(), and a profiled run must land per-bundle spans on a
    # valid Perfetto/Chrome-trace timeline
    _validate_prometheus(srv.metrics_prometheus(), srv.metrics())
    _profiled_trace_smoke(verbose=verbose)
    if verbose:
        print(f"serving.smoke,{nreq},hit_rate={cache['hit_rate']:.3f},"
              f"steady_misses={m['steady_misses']},"
              f"occupancy={m['occupancy']:.2f},"
              f"warm_steps={warm_steps},cold_steps={cold_steps}",
              flush=True)
    return m


def check() -> bool:
    """``benchmarks/run.py --check`` hook: the functional serving
    invariants gate at a scaled-down request count (deterministic on
    any host); latency is printed as INFO only — wall-clock serving
    numbers are host properties, same rationale as the
    REPRO_PERF_CHECK demotion in ensemble_bench."""
    try:
        # 2048 requests = 16 bundles -> 2 cold compiles cap the hit
        # rate at 14/16; the scale-free gates (zero steady-state
        # recompiles, occupancy, warm-start win) are unchanged
        m = smoke(nreq=2048, verbose=False, hit_rate_floor=0.85)
    except AssertionError as e:
        print(f"check.serving.smoke,FAIL,{e}", flush=True)
        return False
    cache = m["trace_cache"]
    print(f"check.serving.smoke,PASS,"
          f"hit_rate={cache['hit_rate']:.3f},"
          f"steady_misses={m['steady_misses']},"
          f"occupancy={m['occupancy']:.2f}", flush=True)
    print(f"check.serving.latency,INFO,"
          f"p50_s={m['latency_p50_s']:.4f},"
          f"p99_s={m['latency_p99_s']:.4f}", flush=True)
    return True


if __name__ == "__main__":
    import json
    import sys
    jax.config.update("jax_enable_x64", True)
    if "--smoke" in sys.argv[1:]:
        smoke()
        sys.exit(0)
    for row in run():
        print(",".join(str(x) for x in row))
    if json_artifact:
        path, payload = json_artifact
        with open(path, "w") as fh:
            json.dump(payload, fh, indent=2)
        print(f"wrote {path}")
