"""Autotune-cache population and CI verification (the measured half of
cost-model-driven dispatch).

``benchmarks/run.py --tune`` calls :func:`tune`: every OP_TABLE op is
timed on both dispatch backends (best-of-reps MIN, the same noise-robust
statistic as ensemble_bench) over a grid of shape signatures — the
pallas side additionally over a couple of tile candidates — and the
winners land in ``.autotune/interpret.json`` via
:class:`repro.core.autotune.AutotuneCache` (committed like the BENCH
files, so ``backend='auto'`` resolves from measurements, not just the
analytical model).

``benchmarks/run.py --check`` calls :func:`check`: every committed
entry is re-measured and its recorded winner must still win within the
same >20% slack discipline as the BENCH gate — the fresh
loser/winner time ratio must stay above ``REGRESSION_SLACK *
min(committed_ratio, RATIO_CAP)``.  Entries whose tiled axis is below
``GATE_MIN_AXIS`` — or whose committed winner runs in under
``GATE_MIN_TIME`` (a few-hundred-us op flips winner under transient
host load no matter how decisive its committed ratio looks; the axis
threshold alone mis-scores fast streaming ops, which finish ~50x
sooner than a block op over the same axis) — run in timer-noise
territory and are informational, and ``REPRO_PERF_CHECK=info`` demotes
all timing verdicts (mirroring ensemble_bench.check)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import opcost
from repro.core import autotune
from repro.core import dispatch as dp
from repro.core.policies import ExecPolicy, XLA_FUSED

REGRESSION_SLACK = 0.8
RATIO_CAP = 1.25
GATE_MIN_AXIS = 4096        # same rationale as ensemble_bench.GATE_MIN_NSYS
GATE_MIN_TIME = 500e-6      # committed-winner runtime noise floor [s]

DEVICE = "interpret"        # the only measurable device on this host

STREAM_N = (4096, 262144)
GJ_NSYS = (512, 4096, 32768)
SOA_NSYS = (512, 4096, 32768)


def _time(fn, *a, reps=3):
    """Best-of-reps wall time (MIN), each rep synced — see
    ensemble_bench._time for why MIN and not mean."""
    jax.block_until_ready(fn(*a))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*a))
        best = min(best, time.perf_counter() - t0)
    return best


def _pallas_policy(op: str, tile: int) -> ExecPolicy:
    kw = {"backend": "pallas", "interpret": True}
    if op in opcost.BATCHED_OPS:
        kw["batch_tile"] = tile
    elif op in opcost.REDUCTION_OPS:
        kw["reduce_tile"] = tile
    else:
        kw["block_elems"] = tile
    return ExecPolicy(**kw)


def _tiles_for(op: str, axis_len: int):
    top = opcost._lane_ceil(axis_len)
    if op in opcost.BATCHED_OPS:
        cands = {min(512, top), top}
    else:
        cands = {min(8 * 128, top), min(top, 1 << 16)}
    return sorted(cands)


def _cases():
    """Yield (op, args) covering every OP_TABLE op over the shape grid.
    ``args`` are the public-wrapper positional arguments — the same
    tuple opcost.signature consumes, so tuner keys and auto-dispatch
    keys agree by construction."""

    def rnd(i, shape):
        return jax.random.normal(jax.random.PRNGKey(i), shape)

    for n in STREAM_N:
        x, y, z = rnd(1, (n,)), rnd(2, (n,)), rnd(3, (n,))
        w = jnp.abs(y) + 0.1
        m = (x > 0).astype(x.dtype)
        coeffs = [0.3, -1.2, 2.5]
        yield "linear_sum", (2.0, x, -0.5, y)
        yield "linear_combination", (coeffs, [x, y, z])
        yield "scale_add_multi", (coeffs, x, [x, y, z])
        yield "axpy", (1.7, x, y)
        yield "dot", (x, y)
        yield "wrms_norm", (x, w)
        yield "wrms_norm_mask", (x, w, m)
        yield "dot_prod_multi", (x, [y, z, w])
        yield "wrms_ss", (x, w)
    for b in (3, 8, 16, 24):
        for nsys in GJ_NSYS:
            A = rnd(b, (b, b, nsys)) * 0.05
            A = jnp.eye(b)[:, :, None] - A        # diagonally dominant
            r = rnd(b + 1, (b, nsys))
            yield "block_solve_soa", (A, r)
            if b <= 16 and nsys <= 4096:
                yield "block_inverse_soa", (A,)
            if b <= 8 and nsys <= 4096:
                yield "blockdiag_spmv_soa", (A, r)
    for n in (3, 8):
        for nsys in SOA_NSYS:
            zz, ff, psi = rnd(20, (n, nsys)), rnd(21, (n, nsys)), \
                rnd(22, (n, nsys))
            gmb = jnp.abs(rnd(23, (nsys,))) + 0.1
            ww = jnp.abs(rnd(24, (n, nsys))) + 0.1
            mb = rnd(25, (nsys,)) > 0.3
            yield "newton_residual_soa", (zz, ff, psi, gmb, True)
            if nsys >= 4096:
                yield "masked_update_wrms_soa", (zz, ff, ww, mb)
                yield "wrms_soa", (zz, ww)
            if nsys == 4096:
                q1 = 6
                Wh = rnd(26, (q1, q1, nsys))
                Zh = rnd(27, (q1, n, nsys))
                yield "history_rescale_soa", (Wh, Zh, mb)
    # sparse: banded CSR + a small shared-pattern BSR ensemble
    from repro.core.sunmatrix import SparseCSR
    for ncsr in (133, 1024):
        band = np.abs(np.arange(ncsr)[:, None] - np.arange(ncsr)) <= 2
        dense = np.asarray(rnd(30, (ncsr, ncsr))) * band
        csr = SparseCSR.from_dense(dense)
        xs = rnd(31, (ncsr,))
        yield "csr_spmv", (csr.data, xs, csr.pattern)
    nblk, bb = 5, 3
    brows, bcols = zip(*[(i, j) for i in range(nblk)
                         for j in range(nblk) if abs(i - j) <= 1])
    bpat = (tuple(brows), tuple(bcols), nblk)
    for nsys in (512, 4096):
        Vb = rnd(32, (len(brows), bb, bb, nsys)) + \
            jnp.where((jnp.asarray(brows) == jnp.asarray(bcols))
                      [:, None, None, None],
                      (bb + 2.0) * jnp.eye(bb)[None, :, :, None], 0.0)
        xb = rnd(33, (nblk, bb, nsys))
        yield "bsr_spmv_soa", (Vb, xb, bpat)
        yield "bsr_block_jacobi_inverse_soa", (Vb, bpat)


def _wrapper(op):
    """The public dispatch wrapper for ``op`` with (args..., policy)."""
    fns = {
        "newton_residual_soa": lambda z, f, p, g, neg, pol:
            dp.newton_residual_soa(z, f, p, g, pol, negate=neg),
        "masked_update_wrms_soa": lambda z, dz, w, m, pol:
            jnp.concatenate([a.ravel() for a in
                             dp.masked_update_wrms_soa(z, dz, w, m, pol)]),
        "scale_add_multi": lambda c, x, ys, pol:
            jnp.stack(dp.scale_add_multi(c, x, ys, pol)),
    }
    if op in fns:
        return fns[op]
    return lambda *a: getattr(dp, op)(*a)


def _measure_case(op, args, reps=3):
    """(t_jnp, t_pallas_best, best_tile) for one (op, args)."""
    call = _wrapper(op)
    sig = opcost.signature(op, args)
    t_jnp = _time(lambda: call(*args, XLA_FUSED), reps=reps)
    best_t, best_tile = float("inf"), 0
    for tile in _tiles_for(op, sig.axis_len):
        t = _time(lambda: call(*args, _pallas_policy(op, tile)), reps=reps)
        if t < best_t:
            best_t, best_tile = t, tile
    return sig, t_jnp, best_t, best_tile


def tune(reps: int = 3, verbose: bool = True):
    """Measure the full grid and (re)write ``.autotune/interpret.json``.
    Returns the cache."""
    cache = autotune.AutotuneCache(DEVICE)
    for op, args in _cases():
        sig, t_jnp, t_pal, tile = _measure_case(op, args, reps=reps)
        entry = autotune.Entry(sig=sig, t_jnp=t_jnp, t_pallas=t_pal,
                               tile=tile)
        cache.put(entry)
        if verbose:
            print(f"tune.{sig.key()},{entry.winner},"
                  f"jnp_us={t_jnp * 1e6:.0f},pallas_us={t_pal * 1e6:.0f},"
                  f"tile={tile}", flush=True)
    path = cache.save()
    audit = autotune.model_audit(cache)
    if verbose:
        print(f"tune.saved,{len(cache.entries)},{path}", flush=True)
        print(f"tune.model_agreement,"
              f"{audit['model_agree']}/{audit['model_total']},"
              f"{audit['model_agreement']:.2f}", flush=True)
    autotune.reset_resolver(DEVICE)       # pick up the fresh cache
    return cache


def check() -> bool:
    """CI gate: every committed autotune entry's recorded winner must
    still win on re-measure, within the BENCH slack discipline (one
    retry; sub-GATE_MIN_AXIS entries and REPRO_PERF_CHECK=info are
    informational)."""
    import os
    soft = os.environ.get("REPRO_PERF_CHECK", "").lower() == "info"
    cache = autotune.AutotuneCache(DEVICE).load()
    if not cache.entries:
        print("check.autotune,FAIL,no committed cache entries "
              "(run: python -m benchmarks.run --tune)", flush=True)
        return False
    ok = True
    for entry in cache.entries.values():
        committed_adv = max(entry.ratio, 1.0 / entry.ratio)
        floor = REGRESSION_SLACK * min(committed_adv, RATIO_CAP)
        gating = (entry.sig.axis_len >= GATE_MIN_AXIS and
                  min(entry.t_jnp, entry.t_pallas) >= GATE_MIN_TIME and
                  not soft)
        args = _args_for(entry.sig)
        if args is None:                  # grid changed under the cache
            print(f"check.autotune.{entry.sig.key()},STALE,"
                  f"no generator for this signature — re-tune", flush=True)
            ok &= not gating
            continue
        good, fresh_adv = False, 0.0
        for _attempt in range(2):
            _sig, t_jnp, t_pal, _tile = _measure_case(entry.sig.op, args,
                                                      reps=2)
            tw, tl = (t_jnp, t_pal) if entry.winner == "jnp" \
                else (t_pal, t_jnp)
            fresh_adv = tl / tw
            good = fresh_adv >= floor
            if good:
                break
        ok &= good or not gating
        verdict = ("PASS" if gating else "INFO") if good else \
            ("FAIL" if gating else "INFO")
        print(f"check.autotune.{entry.sig.key()},{verdict},"
              f"winner={entry.winner},fresh={fresh_adv:.2f},"
              f"floor={floor:.2f}", flush=True)
    return ok


def _args_for(sig: opcost.OpSig):
    """Rebuild the generator args matching ``sig`` (None if the tuning
    grid no longer produces this signature)."""
    for op, args in _cases():
        if op == sig.op and opcost.signature(op, args).key() == sig.key():
            return args
    return None


if __name__ == "__main__":
    import sys
    jax.config.update("jax_enable_x64", True)
    if "--check" in sys.argv:
        sys.exit(0 if check() else 1)
    tune()
