"""Render the EXPERIMENTS.md §Dry-run + §Roofline tables from results."""
from __future__ import annotations

import glob
import json
import os

DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def fmt_bytes(b):
    if b >= 1e12:
        return f"{b/1e12:.2f}TB"
    if b >= 1e9:
        return f"{b/1e9:.1f}GB"
    return f"{b/1e6:.0f}MB"


def rows(mesh=None, tagged=False):
    out = []
    for f in sorted(glob.glob(os.path.join(DIR, "*.json"))):
        base = os.path.basename(f)[:-5]
        if (base.count("__") != 2) != tagged:
            continue
        r = json.load(open(f))
        if mesh and r.get("mesh") != mesh:
            continue
        out.append(r)
    return out


def dryrun_table():
    print("| arch | shape | 16x16 (256) | 2x16x16 (512) | per-chip state+args | notes |")
    print("|---|---|---|---|---|---|")
    singles = {(r["arch"], r["shape"]): r for r in rows("single")}
    multis = {(r["arch"], r["shape"]): r for r in rows("multi")}
    skips = {(r["arch"], r["shape"]): r for r in rows()
             if r.get("skipped")}
    keys = sorted(set(singles) | set(multis) | set(skips))
    for k in keys:
        a, s = k
        if k in skips:
            print(f"| {a} | {s} | SKIP | SKIP | - | sub-quadratic-only shape |")
            continue
        rs, rm = singles.get(k), multis.get(k)
        def st(r):
            if r is None:
                return "-"
            return ("compiled" if r.get("ok") else "FAIL") + \
                f" ({r.get('compile_s', 0):.0f}s)"
        mem = "-"
        if rs and rs.get("memory", {}).get("argument_size_in_bytes"):
            m = rs["memory"]
            mem = fmt_bytes(m["argument_size_in_bytes"]) + " + " + \
                fmt_bytes(m.get("temp_size_in_bytes", 0)) + " temp"
        print(f"| {a} | {s} | {st(rs)} | {st(rm)} | {mem} | |")


def roofline_table():
    print("| arch | shape | t_compute | t_memory | t_collective | bottleneck"
          " | useful | MFU-bound |")
    print("|---|---|---|---|---|---|---|---|")
    for r in rows("single"):
        if "roofline" not in r:
            continue
        rl = r["roofline"]
        print(f"| {r['arch']} | {r['shape']} | {rl['t_compute']:.3g}s | "
              f"{rl['t_memory']:.3g}s | {rl['t_collective']:.3g}s | "
              f"{rl['bottleneck']} | {rl['useful_ratio']:.2f} | "
              f"{rl['mfu_bound']:.2%} |")


def perf_table():
    print("| run | t_compute | t_memory | t_collective | bottleneck | "
          "MFU-bound | AG/AR/A2A (GB per chip) |")
    print("|---|---|---|---|---|---|---|")
    for r in rows(tagged=True):
        if "roofline" not in r:
            continue
        rl = r["roofline"]
        hc = r.get("hlocost", {})
        name = f"{r['arch'][:12]} {r['shape']} {r.get('profile','')}"
        coll = (f"{hc.get('coll_all-gather',0)/1e9:.0f}/"
                f"{hc.get('coll_all-reduce',0)/1e9:.0f}/"
                f"{hc.get('coll_all-to-all',0)/1e9:.0f}")
        print(f"| {name} | {rl['t_compute']:.3g}s | {rl['t_memory']:.3g}s | "
              f"{rl['t_collective']:.3g}s | {rl['bottleneck']} | "
              f"{rl['mfu_bound']:.2%} | {coll} |")


if __name__ == "__main__":
    import sys
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("dryrun", "all"):
        print("### Dry-run matrix\n")
        dryrun_table()
    if which in ("roofline", "all"):
        print("\n### Roofline (single pod)\n")
        roofline_table()
    if which in ("perf", "all"):
        print("\n### Perf iterations\n")
        perf_table()
