"""Sparse-vs-dense batched Newton solve: time + bytes vs fill fraction.

The EnsembleSparseGJ claim quantified (ISSUE 4 / the ECP paper's
exploit-the-block-sparsity point): for an ensemble of nsys systems of
size b sharing one banded sparsity pattern, compare

* dense   — batched Gauss-Jordan solve on the full (b, b, nsys) blocks
            (the BlockDiagGJ lsetup+lsolve path), O(b^2) bytes/system;
* sparse  — the static-pattern LU split (symbolic host-side, numeric
            factor + two triangular sweeps unrolled over the pattern),
            O(nnz_factored) bytes/system.

Sweeps b in {8, 16, 32} x nsys in {512, 4096} x half-bandwidth in
{1, 2, 4} (fill fractions ~ 10-60% depending on b) and emits
``BENCH_sparse.json`` via the run.py json_artifact hook.

Rows: ``sparse.b{b}.nsys{nsys}.fill{pct}, sparse_us, derived`` where
derived carries the dense time, the byte counts, and the ratios.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch as dv
from repro.core import spsolve

json_artifact = None


def _banded_pattern(n: int, halfwidth: int) -> np.ndarray:
    i = np.arange(n)
    return np.abs(i[:, None] - i[None, :]) <= halfwidth


def _t(fn, *a, reps: int = 5):
    jax.block_until_ready(fn(*a))
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn(*a)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    global json_artifact
    rows, payload = [], []
    key = jax.random.PRNGKey(0)
    for b in (8, 16, 32):
        for nsys in (512, 4096):
            for hw in (1, 2, 4):
                P = _banded_pattern(b, hw)
                fill = float(P.sum()) / (b * b)
                enc = spsolve.encode_pattern(P)
                plan = spsolve.symbolic_lu(*enc, order=True, fill=True)
                # diagonally dominant Newton-like blocks on the pattern
                A = jax.random.normal(key, (b, b, nsys)) * \
                    jnp.asarray(P)[:, :, None] + \
                    (2.0 * hw + 3.0) * jnp.eye(b)[:, :, None]
                r = jax.random.normal(jax.random.PRNGKey(1), (b, nsys))

                dense = jax.jit(lambda A, r: dv.block_solve_soa(A, r))
                t_dense = _t(dense, A, r)

                @jax.jit
                def sparse(A, r):
                    f = spsolve.numeric_lu(
                        plan, spsolve.gather_filled(plan, A))
                    return spsolve.lu_solve(plan, f, r)

                t_sparse = _t(sparse, A, r)
                err = float(jnp.max(jnp.abs(sparse(A, r) - dense(A, r))))
                dense_bytes = b * b * nsys * 8
                sparse_bytes = plan.nnz_factored * nsys * 8
                rec = dict(b=b, nsys=nsys, halfwidth=hw,
                           fill=round(fill, 4),
                           nnz=int(np.asarray(P).sum()),
                           nnz_factored=plan.nnz_factored,
                           dense_us=round(t_dense, 1),
                           sparse_us=round(t_sparse, 1),
                           dense_bytes=dense_bytes,
                           sparse_bytes=sparse_bytes,
                           bytes_ratio=round(sparse_bytes / dense_bytes,
                                             4),
                           speedup=round(t_dense / max(t_sparse, 1e-9),
                                         3),
                           max_err=err)
                payload.append(rec)
                rows.append((
                    f"sparse.b{b}.nsys{nsys}.fill{int(100 * fill)}",
                    f"{t_sparse:.1f}",
                    f"dense_us={t_dense:.1f},bytes={sparse_bytes}/"
                    f"{dense_bytes},speedup={rec['speedup']},"
                    f"err={err:.1e}"))
    json_artifact = ("BENCH_sparse.json", {
        "bench": "sparse_vs_dense_batched_newton_solve",
        "sweep": payload})
    return rows


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
