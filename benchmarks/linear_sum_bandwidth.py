"""Table-1 analog: achieved memory bandwidth of N_VLinearSum.

Paper: N_VLinearSum is the costliest integrator op; achieved vs
theoretical-peak HBM bandwidth explains V100-vs-MI100 behavior.  Here we
measure achieved CPU bandwidth of the jitted op (3 streams: 2 reads +
1 write) and report the projected TPU v5e fraction for the same op
assuming the measured achieved/peak ratio carries the same shape.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import vector as nv

V5E_PEAK = 819e9  # bytes/s HBM

SIZES = [10 ** 5, 10 ** 6, 10 ** 7]


def run():
    rows = []
    op = jax.jit(lambda x, y: nv.linear_sum(2.0, x, -1.0, y))
    for n in SIZES:
        x = jnp.zeros((n,), jnp.float64)
        y = jnp.ones((n,), jnp.float64)
        jax.block_until_ready(op(x, y))
        reps = max(3, int(3e7 / n))
        t0 = time.perf_counter()
        for _ in range(reps):
            r = op(x, y)
        jax.block_until_ready(r)
        dt = (time.perf_counter() - t0) / reps
        bytes_moved = 3 * n * 8                  # 2 reads + 1 write
        bw = bytes_moved / dt
        rows.append((f"linear_sum.n{n}.achieved_GBps", bw / 1e9,
                     f"per_call_us={dt*1e6:.1f}"))
    # v5e projection: the op at n=1e7 in bf16 moves 3*n*2 bytes; at peak
    # HBM that is the floor time on TPU — report it as 'derived'
    n = 10 ** 7
    t_tpu = 3 * n * 2 / V5E_PEAK
    rows.append(("linear_sum.n1e7.v5e_roofline_us", t_tpu * 1e6,
                 "bf16,3streams,819GBps"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
